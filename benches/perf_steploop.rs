//! §Perf bench: the native step-loop cost model.
//!
//! Measures training tokens/sec per method × thread count × worker
//! count through the `Backend` trait — the artifact-free default build
//! runs it with no XLA and no Python, so the perf trajectory of the
//! pure-rust engine is tracked from the same binary CI compiles anyway.
//! Also reports the pure data-pipeline rate (tokens/sec the loader can
//! produce) to show the host side is never the bottleneck.
//!
//! `--workers 0` is the plain single-engine step loop; a nonzero count
//! runs the data-parallel `ShardedBackend` (same losses bit for bit).
//!
//! Emits `BENCH_steploop.json` (machine-readable trajectory point) next
//! to the CSV:
//!
//!   cargo bench --bench perf_steploop -- --steps 20
//!   cargo bench --bench perf_steploop -- --threads 1,2,4,8 --methods sltrain
//!   cargo bench --bench perf_steploop -- --workers 0,2,4 --methods full

use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::data::Pipeline;
use sltrain::linalg::{simd, SupportPattern};
use sltrain::util::cli::Cli;
use sltrain::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    let a = Cli::new("perf_steploop", "native step-loop throughput per method x thread count")
        .opt("steps", "20", "measured steps per cell (after 2 warmup)")
        .opt("configs", "tiny", "comma-separated scale points")
        .opt("methods", "full,lowrank,sltrain,relora,galore", "comma-separated methods")
        .opt("threads", "1,2,4", "comma-separated thread counts")
        .opt(
            "workers",
            "0",
            "comma-separated data-parallel worker counts (0 = plain single engine)",
        )
        .opt("batch", "8", "train batch rows")
        .opt("optim-bits", "0", "Adam moment precision: 32 | 8 (0 = auto)")
        .opt("galore-every", "0", "GaLore projector refresh period (0 = default)")
        .opt("support", "random", "sltrain support pattern: random | n:m (e.g. 2:4)")
        .opt("json", "BENCH_steploop.json", "machine-readable output path")
        .opt("csv", "results/perf_steploop.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps").max(1);
    let batch = a.usize("batch").max(1);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let support = SupportPattern::parse(&a.str("support")).map_err(anyhow::Error::msg)?;
    let simd_path = simd::active_path().name();
    println!("simd microkernel path: {simd_path} (SLTRAIN_SIMD=off forces scalar)");

    // data pipeline rate, standalone
    let mut pipe0 = Pipeline::build(4096, 7);
    let t0 = std::time::Instant::now();
    let mut n = 0usize;
    while t0.elapsed().as_secs_f64() < 0.5 {
        pipe0.train.next_batch(8, 128);
        n += 8 * 128;
    }
    let pipe_rate = n as f64 / t0.elapsed().as_secs_f64();
    println!("data pipeline alone: {pipe_rate:.0} tokens/sec ({cores} cores)");

    let mut t = Table::new(
        "§Perf — native step loop (tokens/sec, higher is better)",
        &["config", "method", "threads", "workers", "tok/s", "step ms", "speedup vs first"],
    );
    let mut results: Vec<Json> = Vec::new();
    for cfgn in a.str("configs").split(',') {
        let p = match preset(cfgn) {
            Some(p) => p,
            None => {
                println!("[skip] unknown preset {cfgn:?}");
                continue;
            }
        };
        for method in a.str("methods").split(',') {
            // baseline = the first thread count listed (put 1 first to
            // read the column as parallel speedup)
            let mut base_tps = 0.0f64;
            for threads_s in a.str("threads").split(',') {
                let threads: usize = match threads_s.trim().parse() {
                    Ok(v) => v,
                    Err(_) => {
                        println!("[skip] bad thread count {threads_s:?}");
                        continue;
                    }
                };
                for workers_s in a.str("workers").split(',') {
                    let workers: usize = match workers_s.trim().parse() {
                        Ok(v) => v,
                        Err(_) => {
                            println!("[skip] bad worker count {workers_s:?}");
                            continue;
                        }
                    };
                    let spec = BackendSpec::Native {
                        preset: p.clone(),
                        method: method.to_string(),
                        batch,
                        lr: 3e-3,
                        total_steps: 2000,
                        threads,
                        optim_bits: a.usize("optim-bits"),
                        galore_every: a.usize("galore-every"),
                        support,
                        workers,
                    };
                    let mut be: Box<dyn Backend> = match backend::open(spec) {
                        Ok(be) => be,
                        Err(e) => {
                            println!("[skip] {cfgn}/{method}: {e}");
                            continue;
                        }
                    };
                    be.init_state(42)?;
                    let seq = be.seq_len();
                    let mut pipe = Pipeline::build(be.preset().vocab, 7);
                    for w in 0..2 {
                        let toks = pipe.train.next_batch(batch, seq);
                        be.train_step(w, &toks)?;
                    }
                    let t1 = std::time::Instant::now();
                    for st in 0..steps {
                        let toks = pipe.train.next_batch(batch, seq);
                        be.train_step(2 + st as i32, &toks)?;
                    }
                    let dt = t1.elapsed().as_secs_f64();
                    let tps = (steps * batch * seq) as f64 / dt;
                    let optim_bits = be.mem_report().map(|m| m.optim_bits).unwrap_or(0);
                    if base_tps == 0.0 {
                        base_tps = tps;
                    }
                    t.row(vec![
                        cfgn.to_string(),
                        method.to_string(),
                        threads.to_string(),
                        workers.to_string(),
                        fmt(tps, 0),
                        fmt(dt / steps as f64 * 1e3, 2),
                        fmt(tps / base_tps, 2),
                    ]);
                    println!("  [{cfgn}/{method} x{threads}t w{workers}] {tps:.0} tok/s");
                    results.push(obj(vec![
                        ("config", s(cfgn)),
                        ("method", s(method)),
                        ("threads", num(threads as f64)),
                        ("workers", num(workers as f64)),
                        ("optim_bits", num(optim_bits as f64)),
                        ("support", s(&support.label())),
                        ("tokens_per_sec", num(tps)),
                        ("step_ms", num(dt / steps as f64 * 1e3)),
                    ]));
                }
            }
        }
    }
    t.print();
    t.save_csv(&a.str("csv"))?;

    let report = obj(vec![
        ("bench", s("perf_steploop")),
        ("steps", num(steps as f64)),
        ("batch", num(batch as f64)),
        ("cores", num(cores as f64)),
        ("simd", s(simd_path)),
        ("support", s(&support.label())),
        ("pipeline_tokens_per_sec", num(pipe_rate)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(a.str("json"), report.to_string())?;
    println!("\n[json saved to {}]", a.str("json"));
    println!(
        "target: tokens/sec scales with threads (losses stay bit-identical);\n\
         pipeline rate stays orders of magnitude above the step loop."
    );
    Ok(())
}

//! §Perf bench: the L3 step-loop cost model.
//!
//! Compares the two execution paths per model scale:
//!   literal  — host Literals in/out every step (simple, the default)
//!   device   — device-resident params/opt via `execute_b_untupled`
//!              (the patched xla crate): per-step host traffic is tokens
//!              in + scalar loss out only.
//! Also reports the pure data-pipeline rate (tokens/sec the loader can
//! produce) to show L3 is never the bottleneck.
//!
//!   cargo bench --bench perf_steploop -- --steps 20

use std::path::Path;

use sltrain::bench::{fmt, Table};
use sltrain::data::Pipeline;
use sltrain::runtime::{Artifact, Runtime};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("perf_steploop", "literal vs device-resident step loop")
        .opt("steps", "20", "measured steps per path")
        .opt("configs", "tiny", "scale points")
        .opt("csv", "results/perf_steploop.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;
    let steps = a.usize("steps");

    // data pipeline rate, standalone
    let mut pipe0 = Pipeline::build(4096, 7);
    let t0 = std::time::Instant::now();
    let mut n = 0usize;
    while t0.elapsed().as_secs_f64() < 0.5 {
        pipe0.train.next_batch(8, 128);
        n += 8 * 128;
    }
    let pipe_rate = n as f64 / t0.elapsed().as_secs_f64();
    println!("data pipeline alone: {:.0} tokens/sec", pipe_rate);

    let mut t = Table::new(
        "§Perf — step-loop paths (tokens/sec, higher is better)",
        &["config", "literal tok/s", "device tok/s", "speedup", "pipeline headroom"],
    );
    for cfgn in a.str("configs").split(',') {
        let dir = format!("artifacts/{cfgn}_sltrain");
        if !Path::new(&dir).exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut art = Artifact::load(Path::new(&dir))?;
        let batch = art.entry("train_step")?.batch;
        let seq = art.manifest.seq_len();
        let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);

        // literal path
        let mut state = art.init_state(&rt, 42)?;
        for w in 0..2 {
            let toks = pipe.train.next_batch(batch, seq);
            art.train_step(&rt, &mut state, w, &toks)?;
        }
        let t1 = std::time::Instant::now();
        for s in 0..steps {
            let toks = pipe.train.next_batch(batch, seq);
            art.train_step(&rt, &mut state, 2 + s as i32, &toks)?;
        }
        let lit_tps = (steps * batch * seq) as f64 / t1.elapsed().as_secs_f64();

        // device-resident path
        let state2 = art.init_state(&rt, 42)?;
        let mut dstate = art.to_device(&rt, &state2)?;
        for w in 0..2 {
            let toks = pipe.train.next_batch(batch, seq);
            art.train_step_device(&rt, &mut dstate, w, &toks)?;
        }
        let t2 = std::time::Instant::now();
        for s in 0..steps {
            let toks = pipe.train.next_batch(batch, seq);
            art.train_step_device(&rt, &mut dstate, 2 + s as i32, &toks)?;
        }
        let dev_tps = (steps * batch * seq) as f64 / t2.elapsed().as_secs_f64();

        t.row(vec![
            cfgn.to_string(),
            fmt(lit_tps, 0),
            fmt(dev_tps, 0),
            fmt(dev_tps / lit_tps, 2),
            format!("{:.0}x", pipe_rate / dev_tps.max(1.0)),
        ]);
        println!("  [{cfgn}] literal {lit_tps:.0} vs device {dev_tps:.0} tok/s");
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\ntarget: device path >= literal path; pipeline headroom >= 10x\n(L3 must never starve the executable).");
    Ok(())
}

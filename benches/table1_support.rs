//! Table 1: the support-pattern study — why a *random fixed* support
//! works, and what structured (SLoPe-style N:M) support costs.
//!
//! Default mode is artifact-free: the pure-rust native engine trains
//! the full-rank reference plus one sltrain variant per support pattern
//! (`--supports random,2:4`) and reports final perplexity side by side.
//! This is the native random-vs-structured quality row: random support
//! at the paper's delta vs vectorizable 2:4 at density n/m.
//!
//!   cargo bench --bench table1_support
//!
//! The original artifact-based pruning study (L0 truncation, top-vs-
//! random residual supports, frozen-L0 sparse training) still exists
//! behind `--artifact-study`; it needs the `xla` cargo feature and
//! `make artifacts`:
//!
//!   cargo bench --features xla --bench table1_support -- --artifact-study

use anyhow::Result;
use sltrain::backend::{self, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::coordinator::trainer::quick_train;
use sltrain::linalg::SupportPattern;
use sltrain::util::cli::{Args, Cli};

fn main() -> Result<()> {
    let a = Cli::new("table1_support", "Table 1: support-pattern quality study")
        .opt("config", "tiny", "model preset (native mode)")
        .opt("steps", "120", "training steps per variant (native mode)")
        .opt("batch", "4", "train batch rows (native mode)")
        .opt("threads", "0", "step-loop worker threads (0 = auto)")
        .opt("supports", "random,2:4", "comma-separated support patterns to compare")
        .opt("csv", "results/table1.csv", "output CSV")
        .switch(
            "artifact-study",
            "run the legacy artifact-based pruning study instead \
             (requires --features xla and `make artifacts`)",
        )
        .opt("pretrain-steps", "250", "full-rank pretraining steps (artifact study)")
        .opt("sparse-steps", "80", "sparse-only training steps (artifact study)")
        .parse_env();
    if a.flag("artifact-study") {
        return artifact_study(&a);
    }
    native_study(&a)
}

/// Artifact-free support comparison on the native engine.
fn native_study(a: &Args) -> Result<()> {
    let cfg_name = a.str("config");
    let p = preset(&cfg_name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {cfg_name:?}"))?;
    let steps = a.usize("steps").max(1);
    let batch = a.usize("batch").max(1);
    let threads = a.usize("threads");
    let patterns: Vec<SupportPattern> = a
        .str("supports")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| SupportPattern::parse(s.trim()).map_err(anyhow::Error::msg))
        .collect::<Result<_>>()?;

    let run = |method: &str, support: SupportPattern| -> Result<(f64, f64, usize)> {
        let mut be = backend::open(BackendSpec::Native {
            preset: p.clone(),
            method: method.to_string(),
            batch,
            lr: 3e-3,
            total_steps: steps,
            threads,
            optim_bits: 0,
            galore_every: 0,
            support,
            workers: 0,
        })?;
        let r = quick_train(be.as_mut(), steps, 7)?;
        Ok((r.final_ppl, r.tokens_per_sec, r.n_params))
    };

    println!("[1/{}] full-rank reference ({steps} steps)...", patterns.len() + 1);
    let mut rows: Vec<(String, f64, f64, f64, usize)> = vec![];
    let (ppl, tps, n) = run("full", SupportPattern::UniformRandom)?;
    rows.push(("Full-rank".into(), 1.0, ppl, tps, n));
    for (i, pat) in patterns.iter().enumerate() {
        let density = pat.density().unwrap_or(p.delta);
        println!(
            "[{}/{}] sltrain, {} support (density {:.3})...",
            i + 2,
            patterns.len() + 1,
            pat.label(),
            density
        );
        let (ppl, tps, n) = run("sltrain", *pat)?;
        rows.push((format!("SLTrain ({} support)", pat.label()), density, ppl, tps, n));
    }

    let mut t = Table::new(
        "Table 1 — support pattern vs quality (native engine)",
        &["variant", "density", "ppl", "tok/s", "params (M)"],
    );
    for (label, density, ppl, tps, n) in &rows {
        t.row(vec![
            label.clone(),
            fmt(*density, 3),
            fmt(*ppl, 2),
            fmt(*tps, 0),
            fmt(*n as f64 / 1e6, 2),
        ]);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!(
        "\npaper shape: a fixed random support trains to near-full-rank quality;\n\
         structured N:M trades a denser, vectorizable support for the same recipe."
    );
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn artifact_study(_a: &Args) -> Result<()> {
    anyhow::bail!(
        "--artifact-study needs the xla cargo feature:\n  \
         cargo bench --features xla --bench table1_support -- --artifact-study"
    )
}

/// The original Table-1 reproduction: SVD truncation + residual-support
/// pruning/training variants, injected into AOT artifact state.
#[cfg(feature = "xla")]
fn artifact_study(a: &Args) -> Result<()> {
    use std::collections::HashMap;
    use std::path::Path;

    use sltrain::coordinator::metrics::perplexity;
    use sltrain::coordinator::TrainConfig;
    use sltrain::data::Pipeline;
    use sltrain::linalg::{svd, Matrix};
    use sltrain::runtime::{lit_f32, lit_i32, Artifact, Runtime, State};
    use sltrain::util::rng::Rng;

    fn eval_mean(
        rt: &Runtime,
        art: &mut Artifact,
        state: &mut State,
        valid: &[Vec<i32>],
    ) -> Result<f64> {
        let mut total = 0.0;
        for b in valid {
            total += art.eval_loss(rt, state, b)? as f64;
        }
        Ok(total / valid.len() as f64)
    }

    let rt = Runtime::cpu()?;

    // 1. pretrain the full-rank reference
    println!("[1/4] pretraining tiny_full for {} steps...", a.usize("pretrain-steps"));
    let mut full = Artifact::load(Path::new("artifacts/tiny_full"))?;
    let mut pipe = Pipeline::build(full.manifest.preset.vocab, 7);
    let cfg = TrainConfig {
        steps: a.usize("pretrain-steps"),
        eval_every: 0,
        eval_batches: 6,
        log_every: 100,
        ..Default::default()
    };
    let mut state = full.init_state(&rt, 42)?;
    let valid = pipe.valid_set(6, full.entry("train_step")?.batch, full.manifest.seq_len());
    for step in 0..cfg.steps {
        let toks = pipe.train.next_batch(
            full.entry("train_step")?.batch,
            full.manifest.seq_len(),
        );
        full.train_step(&rt, &mut state, step as i32, &toks)?;
    }
    let base_loss = eval_mean(&rt, &mut full, &mut state, &valid)?;
    println!("    full-rank eval ppl {:.2}", perplexity(base_loss));

    // snapshot dense adapted weights
    let rank = full.manifest.preset.rank;
    let delta = full.manifest.preset.delta;
    let weights: Vec<(String, Vec<usize>, Vec<f32>)> = full
        .manifest
        .params
        .iter()
        .filter(|t| t.name.starts_with("layers.") && t.name.ends_with(".w"))
        .map(|t| {
            let v = state.to_f32(&t.name).unwrap();
            (t.name.clone(), t.shape.clone(), v)
        })
        .collect();

    // 2. build variants + evaluate via weight injection into tiny_full
    println!("[2/4] building L0 / pruning variants (rank {rank}, delta {delta})...");
    let mut results: Vec<(String, f64)> = vec![("Full-rank".into(), perplexity(base_loss))];

    // decompose every weight once
    struct Dec {
        name: String,
        shape: Vec<usize>,
        l0: Matrix,
        resid: Matrix,
        b: Matrix,
        a: Matrix,
    }
    let mut decs = vec![];
    for (name, shape, w) in &weights {
        let m = Matrix::from_vec(shape[0], shape[1], w.clone());
        let f = svd(&m);
        let r = rank.min(f.s.len());
        let mut bm = Matrix::zeros(shape[0], r);
        for i in 0..shape[0] {
            for j in 0..r {
                bm[(i, j)] = f.u[(i, j)] * f.s[j];
            }
        }
        let am = Matrix::from_fn(r, shape[1], |i, j| f.vt[(i, j)]);
        let l0 = bm.matmul(&am);
        let resid = m.sub(&l0);
        decs.push(Dec { name: name.clone(), shape: shape.clone(), l0, resid, b: bm, a: am });
    }

    let eval_variant = |full: &mut Artifact,
                        state: &mut State,
                        f: &dyn Fn(&Dec) -> Matrix|
     -> Result<f64> {
        let rt_ref = &rt;
        // inject modified weights, eval, then restore
        let mut saved = HashMap::new();
        for d in &decs {
            saved.insert(d.name.clone(), state.to_f32(&d.name)?);
            let w = f(d);
            state.put(&d.name, lit_f32(&d.shape, &w.data)?);
        }
        let loss = eval_mean(rt_ref, full, state, &valid)?;
        for d in &decs {
            state.put(&d.name, lit_f32(&d.shape, &saved[&d.name])?);
        }
        Ok(loss)
    };

    // L0 only
    let l0_loss = eval_variant(&mut full, &mut state, &|d| d.l0.clone())?;
    results.push(("Low-rank (L0)".into(), perplexity(l0_loss)));

    // helpers to choose supports over the residual
    let top_support = |d: &Dec, nnz: usize| -> Vec<u32> {
        let mut idx: Vec<u32> = (0..d.resid.data.len() as u32).collect();
        idx.sort_by(|&x, &y| {
            d.resid.data[y as usize]
                .abs()
                .partial_cmp(&d.resid.data[x as usize].abs())
                .unwrap()
        });
        let mut top: Vec<u32> = idx[..nnz].to_vec();
        top.sort_unstable();
        top
    };
    // deterministic per (layer, tag) so each weight gets its own support
    let rand_support = |d: &Dec, nnz: usize, tag: u64| -> Vec<u32> {
        let seed = d
            .name
            .bytes()
            .fold(tag, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
        let mut rng = Rng::new(seed);
        rng.sample_without_replacement(d.resid.data.len() as u64, nnz)
            .into_iter()
            .map(|x| x as u32)
            .collect()
    };
    let nnz_of = |d: &Dec| ((delta * d.resid.data.len() as f64).round() as usize).max(1);

    // L0 + top / random sparse pruning (keep residual values at support)
    for (label, random) in [("L0 + top sparse pruning", false), ("L0 + random sparse pruning", true)] {
        let loss = eval_variant(&mut full, &mut state, &|d| {
            let nnz = nnz_of(d);
            let sup = if random {
                rand_support(d, nnz, 11)
            } else {
                top_support(d, nnz)
            };
            let vals: Vec<f32> = sup.iter().map(|&i| d.resid.data[i as usize]).collect();
            let mut w = d.l0.clone();
            w.scatter_add(&sup, &vals);
            w
        })?;
        results.push((label.into(), perplexity(loss)));
    }

    // 3. L0 + sparse TRAINING with top/random support (frozen low-rank)
    println!("[3/4] sparse-only training (frozen L0)...");
    let frozen_dir = Path::new("artifacts/tiny_sltrain_frozen");
    if frozen_dir.exists() {
        for (label, random) in [
            ("L0 + sparse training (top support)", false),
            ("L0 + sparse training (random support)", true),
        ] {
            let mut art = Artifact::load(frozen_dir)?;
            let mut st = art.init_state(&rt, 42)?;
            // inject L0 factors + chosen support (+ zero values) per layer
            for d in &decs {
                let base = d.name.trim_end_matches(".w");
                st.put(&format!("{base}.B"), lit_f32(&[d.b.rows, d.b.cols], &d.b.data)?);
                // undo the alpha/r scale the artifact applies to BA
                let scale = (art.manifest.preset.alpha / art.manifest.preset.rank as f64) as f32;
                let a_unscaled = d.a.scale(1.0 / scale);
                st.put(
                    &format!("{base}.A"),
                    lit_f32(&[d.a.rows, d.a.cols], &a_unscaled.data)?,
                );
                let nnz_art = art
                    .manifest
                    .supports
                    .get(&format!("{base}.idx"))
                    .map(|s| s.nnz)
                    .unwrap_or(nnz_of(d));
                let sup = if random {
                    rand_support(d, nnz_art, 101)
                } else {
                    top_support(d, nnz_art)
                };
                let sup_i32: Vec<i32> = sup.iter().map(|&x| x as i32).collect();
                st.put(&format!("{base}.idx"), lit_i32(&[sup_i32.len()], &sup_i32)?);
                st.put(&format!("{base}.vals"), lit_f32(&[sup_i32.len()], &vec![0.0; sup_i32.len()])?);
            }
            // also inject the non-adapted trained params (embed/head/norms)
            for t in &full.manifest.params {
                if !t.name.ends_with(".w") || !t.name.starts_with("layers.") {
                    let v = state.to_f32(&t.name)?;
                    st.put(&t.name, lit_f32(&t.shape, &v)?);
                }
            }
            let mut pipe2 = Pipeline::build(art.manifest.preset.vocab, 7);
            for step in 0..a.usize("sparse-steps") {
                let toks = pipe2
                    .train
                    .next_batch(art.entry("train_step")?.batch, art.manifest.seq_len());
                art.train_step(&rt, &mut st, step as i32, &toks)?;
            }
            let loss = eval_mean(&rt, &mut art, &mut st, &valid)?;
            results.push((label.into(), perplexity(loss)));
        }
    } else {
        println!("[skip] artifacts/tiny_sltrain_frozen missing — emit with --freeze-lowrank");
    }

    // 4. report
    println!("[4/4] results");
    let mut t = Table::new("Table 1 — pruning vs sparse training, random vs top support", &["variant", "ppl"]);
    for (label, ppl) in &results {
        t.row(vec![label.clone(), fmt(*ppl, 2)]);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: pruning rows catastrophically worse than full-rank;\nsparse-TRAINING rows recover to within ~2x of full-rank; random ≈ top support.");
    Ok(())
}

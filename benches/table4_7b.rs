//! Table 4: LLaMA-7B-scale comparison of 8-bit GaLore vs 8-bit SLTrain.
//!
//! The 7B model cannot train on this testbed (the paper itself needed
//! 4x A100-80G); per DESIGN.md §3 we substitute:
//!   * memory — the Appendix-F estimator at the paper's EXACT 7B dims
//!     (the same model the paper uses for its estimates), and
//!   * ppl/throughput dynamics — a measured 8-bit SLTrain vs 8-bit-free
//!     run at the s60m scale point to show quantized moments don't hurt.
//!
//!   cargo bench --bench table4_7b -- --steps 200

use std::path::Path;

use sltrain::backend::xla_backend::XlaBackend;
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::coordinator::trainer::quick_train;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table4_7b", "Table 4: 7B-scale 8-bit comparison")
        .opt("steps", "60", "measured steps at s60m")
        .opt("csv", "results/table4.csv", "output CSV")
        .parse_env();

    // ---- analytic 7B rows (paper's own estimation methodology) ----
    let p7 = preset("spec7b").unwrap();
    let o8 = MemOptions { eight_bit: true, per_layer: false };
    let gl = estimate(&p7, "galore", o8);
    let sl = estimate(&p7, "sltrain", o8);
    let mut t = Table::new(
        "Table 4 (7B, analytic) — 8-bit optimizer, no per-layer updates",
        &["method", "params(M)", "train mem(G)", "vs galore"],
    );
    t.row(vec![
        "8-bit GaLore".into(),
        fmt(gl.total_params() / 1e6, 0),
        fmt(MemEstimate::gb(gl.train_bytes()), 1),
        "1.00".into(),
    ]);
    t.row(vec![
        "8-bit SLTrain".into(),
        fmt(sl.total_params() / 1e6, 0),
        fmt(MemEstimate::gb(sl.train_bytes()), 1),
        fmt(sl.train_bytes() / gl.train_bytes(), 2),
    ]);
    t.print();
    println!(
        "paper: 62G vs 46G per GPU (26% reduction); ours: {:.0}% reduction of the\nparam+grad+optimizer footprint (activations excluded on both sides).",
        100.0 * (1.0 - sl.train_bytes() / gl.train_bytes())
    );

    // ---- measured 8-bit dynamics at s60m ----
    let steps = a.usize("steps");
    let mut t2 = Table::new(
        &format!("Table 4 (measured, s60m, {steps} steps) — 8-bit Adam fidelity"),
        &["method", "ppl", "tok/s"],
    );
    for (label, dir) in [
        ("SLTrain (f32 Adam)", "artifacts/tiny_sltrain"),
        ("8-bit SLTrain", "artifacts/tiny_sltrain_8bit"),
    ] {
        if !Path::new(dir).exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut be = XlaBackend::open(Path::new(dir))?;
        let r = quick_train(&mut be, steps, 7)?;
        t2.row(vec![label.into(), fmt(r.final_ppl, 2), fmt(r.tokens_per_sec, 0)]);
        println!("  [{label}] ppl {:.2}", r.final_ppl);
    }
    t2.print();
    t2.save_csv(&a.str("csv"))?;
    println!("\npaper shape: 8-bit SLTrain ppl within ~3% of GaLore at equal tokens\n(27.59 vs 26.87); here: 8-bit vs f32 moments nearly identical.");
    Ok(())
}

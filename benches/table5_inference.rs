//! Table 5: inference memory + throughput, SLTrain vs Full-Rank.
//!
//! Paper shape: SLTrain saves parameter memory (more at larger scale) at
//! a modest throughput cost (6-11%), because the factored weights must be
//! densified on the fly during the forward pass.
//!
//!   cargo bench --bench table5_inference -- --iters 15

use std::path::Path;

use sltrain::bench::{fmt, Table};
use sltrain::data::Pipeline;
use sltrain::runtime::{current_rss_bytes, Artifact, Runtime};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table5_inference", "Table 5 inference memory/throughput")
        .opt("iters", "15", "timed forward passes")
        .opt("configs", "tiny", "scale points")
        .opt("csv", "results/table5.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;

    let mut t = Table::new(
        "Table 5 — inference (forward only)",
        &["config", "method", "param MB", "rss MB", "tok/s", "mem vs full", "tok/s vs full"],
    );
    for cfgn in a.str("configs").split(',') {
        let mut full_mem = 0.0f64;
        let mut full_tps = 0.0f64;
        for method in ["full", "sltrain"] {
            let dir = format!("artifacts/{cfgn}_{method}");
            if !Path::new(&dir).exists() {
                println!("[skip] {dir}");
                continue;
            }
            let mut art = Artifact::load(Path::new(&dir))?;
            let batch = art.entry("forward")?.batch;
            let seq = art.manifest.seq_len();
            let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
            let mut state = art.init_state(&rt, 42)?;
            // inference = params only; drop the optimizer state
            let opt: Vec<String> =
                art.manifest.opt_state.iter().map(|t| t.name.clone()).collect();
            for n in &opt {
                state.tensors.remove(n);
            }
            // parameter bytes incl. sparse index storage (paper's model)
            let param_mb = art.manifest.params.iter().map(|t| t.numel() * 4).sum::<usize>()
                as f64
                / 1e6
                + art.manifest.consts.iter().map(|t| t.numel() * 8).sum::<usize>() as f64
                    / 1e6;
            let toks = pipe.valid.next_batch(batch, seq);
            art.forward(&rt, &mut state, &toks)?; // compile + warm
            let t0 = std::time::Instant::now();
            for _ in 0..a.usize("iters") {
                art.forward(&rt, &mut state, &toks)?;
            }
            let dt = t0.elapsed().as_secs_f64();
            let tps = (a.usize("iters") * batch * seq) as f64 / dt;
            let rss = current_rss_bytes() as f64 / 1e6;
            if method == "full" {
                full_mem = param_mb;
                full_tps = tps;
            }
            t.row(vec![
                cfgn.to_string(),
                method.to_string(),
                fmt(param_mb, 1),
                fmt(rss, 0),
                fmt(tps, 0),
                if full_mem > 0.0 {
                    format!("{:+.1}%", 100.0 * (param_mb / full_mem - 1.0))
                } else {
                    "-".into()
                },
                if full_tps > 0.0 {
                    format!("{:+.1}%", 100.0 * (tps / full_tps - 1.0))
                } else {
                    "-".into()
                },
            ]);
            println!("  [{cfgn}/{method}] {tps:.0} tok/s, params {param_mb:.1} MB");
        }
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: memory saving grows with scale (-1.7% at 130M to -36% at 7B),\nthroughput cost stays 6-11%.");
    Ok(())
}

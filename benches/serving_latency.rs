//! §Serving bench: tokens/sec + latency percentiles under open load.
//!
//! Drives the continuous-batching scheduler (fold-for-inference weights,
//! per-sequence KV caches) with the synthetic open-loop load generator
//! and reports generated tokens/sec plus p50/p99 arrival-to-completion
//! latency — the serving analog of the Glentis et al. method × scale
//! grids. Artifact-free: builds the model fresh from a seed, no daemon
//! and no socket involved (the wire protocol is benched e2e in
//! `tests/serve_e2e.rs`; this isolates the decode engine).
//!
//! Emits `BENCH_serving.json`:
//!
//!   cargo bench --bench serving_latency -- --steps 200
//!   cargo bench --bench serving_latency -- --methods sltrain --rate 40
//!
//! `--steps` bounds the scheduler-step count, so CI smokes finish fast.

use sltrain::backend::native::NativeBackend;
use sltrain::backend::Backend;
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::linalg::{simd, SupportPattern};
use sltrain::serve::{run_open_loop, LoadSpec, Scheduler};
use sltrain::util::cli::Cli;
use sltrain::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    let a = Cli::new("serving_latency", "serving tokens/sec + p50/p99 under open-loop load")
        .opt("steps", "200", "scheduler steps per cell (bounds the run)")
        .opt("configs", "tiny", "comma-separated scale points")
        .opt("methods", "sltrain,lowrank,full", "comma-separated methods")
        .opt("support", "random", "sltrain support pattern: random | n:m (e.g. 2:4)")
        .opt("threads", "0", "worker threads (0 = auto)")
        .opt("rate", "20", "request arrivals per second")
        .opt("prompt-len", "16", "prompt tokens per request")
        .opt("max-tokens", "16", "generated tokens per request")
        .opt("max-batch", "4", "concurrent decode slots")
        .opt("seed", "42", "model init + prompt seed")
        .opt("json", "BENCH_serving.json", "machine-readable output path")
        .opt("csv", "results/serving_latency.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps").max(1);
    let support = SupportPattern::parse(&a.str("support")).map_err(anyhow::Error::msg)?;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let simd_path = simd::active_path().name();
    println!("simd microkernel path: {simd_path} ({cores} cores)");

    let mut t = Table::new(
        "§Serving — folded weights, KV-cache decode, continuous batching",
        &["config", "method", "fold", "done", "tok/s", "p50 ms", "p99 ms"],
    );
    let mut results: Vec<Json> = Vec::new();
    for cfgn in a.str("configs").split(',') {
        let p = match preset(cfgn) {
            Some(p) => p,
            None => {
                println!("[skip] unknown preset {cfgn:?}");
                continue;
            }
        };
        for method in a.str("methods").split(',') {
            // folded (the Table-5 serving recipe) vs live factored
            // weights: the fold's speedup is the measured quantity
            for fold in [true, false] {
                let mut be = match NativeBackend::build(
                    p.clone(),
                    method,
                    1,
                    3e-3,
                    2000,
                    a.usize("threads"),
                    32,
                    0,
                    support,
                ) {
                    Ok(be) => be,
                    Err(e) => {
                        println!("[skip] {cfgn}/{method}: {e}");
                        continue;
                    }
                };
                be.init_state(a.u64("seed") as u32)?;
                be.drop_optimizer_state()?;
                if fold {
                    be.fold_weights()?;
                }
                let mut sched = Scheduler::new(be, a.usize("max-batch").max(1));
                let spec = LoadSpec {
                    rate: a.f64("rate").max(0.1),
                    steps,
                    prompt_len: a.usize("prompt-len").max(1),
                    max_tokens: a.usize("max-tokens").max(1),
                    seed: a.u64("seed"),
                };
                let r = run_open_loop(&mut sched, &spec)?;
                let fold_s = if fold { "dense" } else { "live" };
                t.row(vec![
                    cfgn.to_string(),
                    method.to_string(),
                    fold_s.to_string(),
                    format!("{}", r.completed),
                    fmt(r.tokens_per_sec, 0),
                    fmt(r.p50_ms, 2),
                    fmt(r.p99_ms, 2),
                ]);
                println!(
                    "  [{cfgn}/{method} {fold_s}] {} done, {:.0} tok/s, p50 {:.1} ms, \
                     p99 {:.1} ms",
                    r.completed, r.tokens_per_sec, r.p50_ms, r.p99_ms
                );
                results.push(obj(vec![
                    ("config", s(cfgn)),
                    ("method", s(method)),
                    ("folded", Json::Bool(fold)),
                    ("support", s(&support.label())),
                    ("completed", num(r.completed as f64)),
                    ("unfinished", num(r.unfinished as f64)),
                    ("generated_tokens", num(r.generated_tokens as f64)),
                    ("tokens_per_sec", num(r.tokens_per_sec)),
                    ("p50_ms", num(r.p50_ms)),
                    ("p99_ms", num(r.p99_ms)),
                    ("wall_secs", num(r.wall_secs)),
                ]));
            }
        }
    }
    t.print();
    t.save_csv(&a.str("csv"))?;

    let report = obj(vec![
        ("bench", s("serving_latency")),
        ("steps", num(steps as f64)),
        ("rate", num(a.f64("rate"))),
        ("prompt_len", num(a.usize("prompt-len") as f64)),
        ("max_tokens", num(a.usize("max-tokens") as f64)),
        ("max_batch", num(a.usize("max-batch") as f64)),
        ("cores", num(cores as f64)),
        ("simd", s(simd_path)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(a.str("json"), report.to_string())?;
    println!("\n[json saved to {}]", a.str("json"));
    println!(
        "target: dense (folded) rows at or above their live rows in tok/s;\n\
         p99 stays bounded while arrivals queue (open-loop, no coordinated omission)."
    );
    Ok(())
}

//! Figure 12 (Appendix E): layer-level memory and runtime of the SLTrain
//! linear (BA + S) vs full-rank (W) vs low-rank (BA) in an N-layer
//! feed-forward stack — fwd+bwd+SGD step via the mlp_stack artifacts.
//!
//!   cargo bench --bench fig12_layer -- --iters 20

use std::collections::HashMap;
use std::path::Path;

use sltrain::bench::{bench, fmt, Table};
use sltrain::runtime::{lit_f32, Runtime};
use sltrain::util::cli::Cli;
use sltrain::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("fig12_layer", "Fig 12 layer-level memory/runtime")
        .opt("iters", "20", "timed steps per variant")
        .opt("csv", "results/fig12.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;

    let mut t = Table::new(
        "Fig 12 — N-layer FFN stack: state memory + step time",
        &["variant", "params", "state MB", "step ms", "vs ffn mem", "vs ffn time"],
    );
    let mut ffn_mb = 0.0f64;
    let mut ffn_ms = 0.0f64;
    for kind in ["ffn", "lowrank", "sltrain"] {
        let dir = Path::new("artifacts/mlp_stack");
        let man_path = dir.join(format!("stack_{kind}.manifest.json"));
        if !man_path.exists() {
            println!("[skip] {man_path:?}");
            continue;
        }
        // stack manifests have their own shape; load manually
        let man = sltrain::Json::parse(&std::fs::read_to_string(&man_path)?)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let batch = man.req("batch")?.as_usize().unwrap();
        let width = man.req("width")?.as_usize().unwrap();
        let file = man.req("entrypoints")?.req("step")?.req("file")?.as_str().unwrap();
        let inputs: Vec<String> = man.req("entrypoints")?.req("step")?.req("inputs")?
            .as_arr().unwrap().iter().map(|s| s.as_str().unwrap().to_string()).collect();

        // compile
        let proto = xla::HloModuleProto::from_text_file(
            dir.join(file).to_str().unwrap(),
        ).map_err(|e| anyhow::anyhow!("{e}"))?;
        let exe = rt.client.compile(&xla::XlaComputation::from_proto(&proto))
            .map_err(|e| anyhow::anyhow!("{e}"))?;

        // build inputs: x + consts(from support sidecars) + params(random)
        let mut rng = Rng::new(0);
        let mut lits: HashMap<String, xla::Literal> = HashMap::new();
        let x: Vec<f32> = (0..batch * width).map(|_| rng.gaussian() as f32 * 0.1).collect();
        lits.insert("__x".into(), lit_f32(&[batch, width], &x)?);
        let mut n_params = 0usize;
        let mut state_bytes = 0usize;
        for p in man.req("params")?.as_arr().unwrap() {
            let name = p.req("name")?.as_str().unwrap().to_string();
            let shape: Vec<usize> = p.req("shape")?.as_arr().unwrap().iter()
                .map(|d| d.as_usize().unwrap()).collect();
            let n: usize = shape.iter().product();
            n_params += n;
            state_bytes += n * 4;
            let data: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32 * 0.05).collect();
            lits.insert(name, lit_f32(&shape, &data)?);
        }
        if let Some(sups) = man.get("supports").and_then(|s| s.as_obj()) {
            for (name, s) in sups {
                let raw = std::fs::read(dir.join(s.req("file")?.as_str().unwrap()))?;
                let idx: Vec<i32> = raw.chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as i32)
                    .collect();
                state_bytes += idx.len() * 8; // paper stores int64 indices
                lits.insert(
                    name.clone(),
                    sltrain::runtime::lit_i32(&[idx.len()], &idx)?,
                );
            }
        }
        let ordered: Vec<&xla::Literal> = inputs.iter().map(|n| &lits[n]).collect();
        exe.execute::<&xla::Literal>(&ordered)?; // warm
        let r = bench(kind, 2, a.usize("iters"), || {
            let out = exe.execute::<&xla::Literal>(&ordered).unwrap();
            let _ = out[0][0].to_literal_sync().unwrap();
        });
        let mb = state_bytes as f64 / 1e6;
        if kind == "ffn" {
            ffn_mb = mb;
            ffn_ms = r.per_iter_ms();
        }
        t.row(vec![
            kind.to_string(),
            format!("{:.2}M", n_params as f64 / 1e6),
            fmt(mb, 2),
            fmt(r.per_iter_ms(), 2),
            format!("{:.0}%", 100.0 * mb / ffn_mb.max(1e-9)),
            format!("{:.0}%", 100.0 * r.per_iter_ms() / ffn_ms.max(1e-9)),
        ]);
        println!("  [{kind}] {:.2} ms/step, {:.2} MB", r.per_iter_ms(), mb);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: BA+S memory ≈ BA (marginally higher), well under FFN;\nruntime slightly above FFN due to the scatter-add.");
    Ok(())
}

//! Figure 2 (and 5–9): spectrum + residual analysis of PRETRAINED
//! full-rank weights — the empirical motivation for SLTrain.
//!
//! Trains the tiny full-rank model, then for each attention/MLP weight:
//! (a) singular value decay, (b) residual magnitudes after removing the
//! best rank-r approximation, (c) the residual-magnitude CDF with the
//! paper's 97% cut-off.
//!
//!   cargo bench --bench fig2_residual -- --steps 400

use std::path::Path;

use sltrain::analysis::ResidualReport;
use sltrain::bench::{fmt, Table};
use sltrain::coordinator::TrainConfig;
use sltrain::data::Pipeline;
use sltrain::linalg::Matrix;
use sltrain::runtime::{Artifact, Runtime};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("fig2_residual", "Fig 2 residual analysis")
        .opt("steps", "250", "full-rank pretraining steps")
        .opt("rank-frac", "0.25", "rank cut as a fraction of width (paper: 128/512)")
        .opt("csv", "results/fig2.csv", "output CSV (singular values)")
        .parse_env();
    let rt = Runtime::cpu()?;

    println!("pretraining tiny_full for {} steps...", a.usize("steps"));
    let mut art = Artifact::load(Path::new("artifacts/tiny_full"))?;
    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let cfg = TrainConfig {
        steps: a.usize("steps"),
        eval_every: 0,
        eval_batches: 4,
        log_every: 100,
        ..Default::default()
    };
    // train and keep state by re-running with explicit loop
    let mut state = art.init_state(&rt, 42)?;
    let batch = art.entry("train_step")?.batch;
    let seq = art.manifest.seq_len();
    for step in 0..cfg.steps {
        let toks = pipe.train.next_batch(batch, seq);
        art.train_step(&rt, &mut state, step as i32, &toks)?;
    }

    let mut t = Table::new(
        "Fig 2 — per-weight spectrum + residual stats (pretrained full-rank)",
        &["weight", "shape", "top-r energy %", "resid max", "resid mean|.|", "p97 |resid|<="],
    );
    let mut csv = String::from("weight,index,sigma\n");
    for spec in art.manifest.params.clone() {
        if !(spec.name.starts_with("layers.") && spec.name.ends_with(".w")) {
            continue;
        }
        let v = state.to_f32(&spec.name)?;
        let m = Matrix::from_vec(spec.shape[0], spec.shape[1], v);
        let cut = ((spec.shape[1] as f64 * a.f64("rank-frac")).round() as usize).max(1);
        let rep = ResidualReport::compute(&m, cut);
        t.row(vec![
            spec.name.clone(),
            format!("{}x{}", spec.shape[0], spec.shape[1]),
            fmt(100.0 * rep.energy_in_top() as f64, 1),
            fmt(rep.resid_max as f64, 4),
            fmt(rep.resid_mean_abs as f64, 5),
            fmt(rep.p97_threshold as f64, 4),
        ]);
        for (i, s) in rep.singular_values.iter().enumerate() {
            csv.push_str(&format!("{},{},{}\n", spec.name, i, s));
        }
        // print the CDF for the last attention output (the paper's pick)
        if spec.name.contains(&format!("layers.{}.attn.o", art.manifest.preset.n_layers - 1)) {
            println!("\nCDF of |residual| for {} (paper Fig 2c):", spec.name);
            for (thr, frac) in &rep.cdf {
                println!("  |w| <= {:>8.4} : {:>5.1}%", thr, frac * 100.0);
            }
        }
    }
    t.print();
    std::fs::create_dir_all("results")?;
    std::fs::write(a.str("csv"), csv)?;
    println!("\npaper shape: fast singular-value decay then a stable tail; residual\nmagnitudes small + smooth (97% under a small threshold) -> a RANDOM\nsupport can capture the residual (the SLTrain premise).");
    Ok(())
}

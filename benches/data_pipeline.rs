//! §Perf bench: the production data path.
//!
//! Measures every stage that feeds the step loop — BPE merge training,
//! byte-exact tokenization (serial and on the worker pool, with a
//! bit-identity assertion at every thread count), checksummed shard
//! writing, and the memory-mapped `ShardStream` read path — so data
//! never starves the step loop silently: `perf_steploop` reports the
//! consumer rate, this bench reports the producer rate.
//!
//! Emits `BENCH_data.json` (machine-readable trajectory point) next to
//! the CSV:
//!
//!   cargo bench --bench data_pipeline -- --words 40000
//!   cargo bench --bench data_pipeline -- --threads 1,2,4,8
//!
//! Shards are written under a scratch directory inside the target temp
//! dir and removed afterwards.

use sltrain::bench::{fmt, Table};
use sltrain::data::{build_shards, Bpe, CorpusConfig, ShardSet, ShardStream, SynthCorpus};
use sltrain::linalg::ThreadPool;
use sltrain::util::cli::Cli;
use sltrain::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    let a = Cli::new("data_pipeline", "data-path throughput: BPE, tokenize, shard write/read")
        .opt("words", "40000", "corpus words tokenized per measurement")
        .opt("vocab", "1024", "BPE vocab cap")
        .opt("threads", "1,2,4", "comma-separated worker-pool thread counts")
        .opt("shards", "3", "shards written for the write/read measurement")
        .opt("shard-tokens", "50000", "tokens per shard")
        .opt("json", "BENCH_data.json", "machine-readable output path")
        .opt("csv", "results/data_pipeline.csv", "output CSV")
        .parse_env();
    let words = a.usize("words").max(1000);
    let vocab = a.usize("vocab").max(256);
    let corpus = SynthCorpus::new(CorpusConfig { seed: 42, ..Default::default() });
    let sample = corpus.generate_text(words, u64::MAX);
    let data = sample.as_bytes();
    println!("corpus sample: {} bytes ({} words)", data.len(), words);

    let mut t = Table::new(
        "§Perf — data path (tokens/sec and bytes/sec, higher is better)",
        &["stage", "threads", "tokens", "secs", "rate"],
    );
    let mut results: Vec<Json> = Vec::new();

    // 1. BPE merge training (serial by construction: merge order is a
    // sequential greedy argmax)
    let t0 = std::time::Instant::now();
    let bpe = Bpe::train(&sample, vocab);
    let bpe_secs = t0.elapsed().as_secs_f64();
    let bytes_per_sec = data.len() as f64 / bpe_secs;
    t.row(vec![
        "bpe train".into(),
        "1".into(),
        format!("{} vocab", bpe.vocab_size()),
        fmt(bpe_secs, 3),
        format!("{} B/s", fmt(bytes_per_sec, 0)),
    ]);
    results.push(obj(vec![
        ("stage", s("bpe_train")),
        ("threads", num(1.0)),
        ("vocab", num(bpe.vocab_size() as f64)),
        ("bytes_per_sec", num(bytes_per_sec)),
    ]));

    // 2. byte-exact tokenization: serial reference, then the worker
    // pool at each thread count — output must be bit-identical
    let t0 = std::time::Instant::now();
    let reference = bpe.encode_bytes(data);
    let serial_secs = t0.elapsed().as_secs_f64();
    let serial_tps = reference.len() as f64 / serial_secs;
    t.row(vec![
        "tokenize serial".into(),
        "1".into(),
        reference.len().to_string(),
        fmt(serial_secs, 3),
        format!("{} tok/s", fmt(serial_tps, 0)),
    ]);
    results.push(obj(vec![
        ("stage", s("tokenize_serial")),
        ("threads", num(1.0)),
        ("tokens", num(reference.len() as f64)),
        ("tokens_per_sec", num(serial_tps)),
    ]));
    for threads_s in a.str("threads").split(',') {
        let threads: usize = match threads_s.trim().parse() {
            Ok(v) => v,
            Err(_) => {
                println!("[skip] bad thread count {threads_s:?}");
                continue;
            }
        };
        let pool = ThreadPool::new(threads.max(1));
        let t0 = std::time::Instant::now();
        let toks = bpe.encode_bytes_par(data, &pool);
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(
            toks, reference,
            "encode_bytes_par({threads} threads) diverged from serial encode_bytes"
        );
        let tps = toks.len() as f64 / dt;
        t.row(vec![
            "tokenize pool".into(),
            threads.to_string(),
            toks.len().to_string(),
            fmt(dt, 3),
            format!("{} tok/s", fmt(tps, 0)),
        ]);
        println!("  [tokenize x{threads}t] {tps:.0} tok/s (bit-identical to serial)");
        results.push(obj(vec![
            ("stage", s("tokenize_pool")),
            ("threads", num(threads as f64)),
            ("tokens", num(toks.len() as f64)),
            ("tokens_per_sec", num(tps)),
        ]));
    }

    // 3. shard write: full `build_shards` (generate + tokenize + CRC +
    // fsync'd atomic writes)
    let dir = std::env::temp_dir().join(format!("sltrain_data_bench_{}", std::process::id()));
    let n_shards = a.usize("shards").max(1);
    let shard_tokens = a.usize("shard-tokens").max(1000);
    let report = build_shards(&dir, n_shards, shard_tokens, vocab, 42, 1)?;
    t.row(vec![
        "shard write".into(),
        "1".into(),
        report.tokens.to_string(),
        fmt(report.wall_secs, 3),
        format!("{} tok/s", fmt(report.tokens_per_sec, 0)),
    ]);
    results.push(obj(vec![
        ("stage", s("shard_write")),
        ("threads", num(1.0)),
        ("tokens", num(report.tokens as f64)),
        ("tokens_per_sec", num(report.tokens_per_sec)),
    ]));

    // 4. shard read: mmap-open the set and drain one full epoch through
    // the deterministic `ShardStream`
    let set = ShardSet::open(&dir)?;
    let total: usize = set.readers.iter().map(|r| r.len()).sum();
    let mut stream = ShardStream::new(set.readers, 7, vocab)?;
    let t0 = std::time::Instant::now();
    let mut sink = 0i64;
    for _ in 0..total {
        sink += stream.next_token() as i64;
    }
    let dt = t0.elapsed().as_secs_f64();
    let read_tps = total as f64 / dt;
    t.row(vec![
        "shard read".into(),
        "1".into(),
        total.to_string(),
        fmt(dt, 3),
        format!("{} tok/s", fmt(read_tps, 0)),
    ]);
    println!("  [shard read] {read_tps:.0} tok/s (checksum {sink})");
    results.push(obj(vec![
        ("stage", s("shard_read")),
        ("threads", num(1.0)),
        ("tokens", num(total as f64)),
        ("tokens_per_sec", num(read_tps)),
    ]));
    std::fs::remove_dir_all(&dir).ok();

    t.print();
    t.save_csv(&a.str("csv"))?;
    let report = obj(vec![
        ("bench", s("data_pipeline")),
        ("words", num(words as f64)),
        ("vocab", num(vocab as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(a.str("json"), report.to_string())?;
    println!("\n[json saved to {}]", a.str("json"));
    println!(
        "target: every tokenize row is bit-identical to serial (asserted), and\n\
         shard read stays orders of magnitude above the step-loop consumer rate."
    );
    Ok(())
}

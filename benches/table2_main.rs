//! Table 2 + Figure 1: validation perplexity, parameter count and
//! estimated memory for the methods at two scale points.
//!
//! The paper's claim to reproduce (shape, not absolute numbers):
//!   Low-Rank ≫ everything (worst PPL); SLTrain ≈ Full-Rank ≈ GaLore;
//!   ReLoRA in between; SLTrain's params/memory close to Low-Rank.
//!
//! Engine-agnostic: runs on the pure-rust native backend by default (no
//! artifacts needed — all five method rows, relora restarts and the
//! galore projected optimizer included), or on AOT artifact bundles
//! with `--backend xla` (needs the `xla` cargo feature and
//! `make artifacts`).
//!
//!   cargo bench --bench table2_main -- --steps 300
//!   cargo bench --bench table2_main --features xla -- --backend xla

use std::path::Path;

use sltrain::backend::{self, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::coordinator::trainer::quick_train;
use sltrain::linalg::SupportPattern;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table2_main", "Table 2 / Fig 1 reproduction")
        .opt("backend", "native", "engine: native | xla")
        .opt("steps", "120", "train steps per cell")
        .opt("configs", "tiny", "comma-separated scale points")
        .opt("threads", "0", "native step-loop worker threads (0 = auto)")
        .opt("optim-bits", "0", "native Adam moment precision: 32 | 8 (0 = auto)")
        .opt("galore-every", "0", "native GaLore projector refresh period (0 = default)")
        .opt("support", "random", "native sltrain support pattern: random | n:m")
        .opt("csv", "results/table2.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps");
    let engine = a.str("backend");
    let support = SupportPattern::parse(&a.str("support")).map_err(anyhow::Error::msg)?;

    let mut t = Table::new(
        &format!("Table 2 (scaled) — {} steps, synthetic C4, {} backend", steps, engine),
        &["config", "method", "ppl", "param(M)", "est mem(G)", "tok/s"],
    );
    let mut fig1 = Table::new(
        "Fig 1 series — (memory, ppl, params) scatter points",
        &["label", "mem_gb", "ppl", "params_m"],
    );

    for cfg_name in a.str("configs").split(',') {
        for method in ["full", "lowrank", "relora", "galore", "sltrain"] {
            let spec = match engine.as_str() {
                "xla" => {
                    let dir = format!("artifacts/{cfg_name}_{method}");
                    if !Path::new(&dir).exists() {
                        println!("[skip] {dir} (not emitted)");
                        continue;
                    }
                    BackendSpec::Xla { artifact_dir: dir.into() }
                }
                _ => {
                    let p = preset(cfg_name)
                        .ok_or_else(|| anyhow::anyhow!("unknown preset {cfg_name:?}"))?;
                    BackendSpec::Native {
                        preset: p,
                        method: method.to_string(),
                        batch: 8,
                        lr: 3e-3,
                        total_steps: steps.max(1),
                        threads: a.usize("threads"),
                        optim_bits: a.usize("optim-bits"),
                        galore_every: a.usize("galore-every"),
                        support,
                        workers: 0,
                    }
                }
            };
            let mut be = backend::open(spec)?;
            let r = quick_train(be.as_mut(), steps, 7)?;
            let e = estimate(be.preset(), method, MemOptions::default());
            let mem_gb = MemEstimate::gb(e.table2_bytes());
            t.row(vec![
                cfg_name.to_string(),
                method.to_string(),
                fmt(r.final_ppl, 2),
                fmt(r.n_params as f64 / 1e6, 2),
                fmt(mem_gb, 4),
                fmt(r.tokens_per_sec, 0),
            ]);
            fig1.row(vec![
                format!("{cfg_name}/{method}"),
                fmt(mem_gb, 4),
                fmt(r.final_ppl, 2),
                fmt(r.n_params as f64 / 1e6, 2),
            ]);
            println!(
                "  [{cfg_name}/{method}] ppl {:.2} in {:.0}s",
                r.final_ppl, r.wall_secs
            );
        }
    }
    t.print();
    fig1.print();
    t.save_csv(&a.str("csv"))?;
    fig1.save_csv("results/fig1.csv")?;
    println!(
        "\npaper shape check: lowrank worst, sltrain within a few % of full-rank,\nsltrain params/mem well below full-rank (compare columns above)."
    );
    Ok(())
}

//! Table 2 + Figure 1: validation perplexity, parameter count and
//! estimated memory for all five methods at two scale points.
//!
//! The paper's claim to reproduce (shape, not absolute numbers):
//!   Low-Rank ≫ everything (worst PPL); SLTrain ≈ Full-Rank ≈ GaLore;
//!   ReLoRA in between; SLTrain's params/memory close to Low-Rank.
//!
//!   cargo bench --bench table2_main -- --steps 300

use sltrain::bench::{fmt, Table};
use sltrain::coordinator::trainer::quick_train;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::runtime::Runtime;
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table2_main", "Table 2 / Fig 1 reproduction")
        .opt("steps", "120", "train steps per cell")
        .opt("configs", "tiny", "comma-separated scale points")
        .opt("csv", "results/table2.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;
    let steps = a.usize("steps");

    let mut t = Table::new(
        &format!("Table 2 (scaled) — {} steps, synthetic C4", steps),
        &["config", "method", "ppl", "param(M)", "est mem(G)", "tok/s"],
    );
    let mut fig1 = Table::new(
        "Fig 1 series — (memory, ppl, params) scatter points",
        &["label", "mem_gb", "ppl", "params_m"],
    );

    for cfg_name in a.str("configs").split(',') {
        for method in ["full", "lowrank", "relora", "galore", "sltrain"] {
            let dir = format!("artifacts/{cfg_name}_{method}");
            let path = std::path::Path::new(&dir);
            if !path.exists() {
                println!("[skip] {dir} (not emitted)");
                continue;
            }
            let (r, man) = quick_train(&rt, path, steps, 7)?;
            let e = estimate(&man.preset, method, MemOptions::default());
            let mem_gb = MemEstimate::gb(e.table2_bytes());
            t.row(vec![
                cfg_name.to_string(),
                method.to_string(),
                fmt(r.final_ppl, 2),
                fmt(r.n_params as f64 / 1e6, 2),
                fmt(mem_gb, 4),
                fmt(r.tokens_per_sec, 0),
            ]);
            fig1.row(vec![
                format!("{cfg_name}/{method}"),
                fmt(mem_gb, 4),
                fmt(r.final_ppl, 2),
                fmt(r.n_params as f64 / 1e6, 2),
            ]);
            println!(
                "  [{cfg_name}/{method}] ppl {:.2} in {:.0}s",
                r.final_ppl, r.wall_secs
            );
        }
    }
    t.print();
    fig1.print();
    t.save_csv(&a.str("csv"))?;
    fig1.save_csv("results/fig1.csv")?;
    println!(
        "\npaper shape check: lowrank worst, sltrain within a few % of full-rank,\nsltrain params/mem well below full-rank (compare columns above)."
    );
    Ok(())
}

//! Figure 3: actual training memory footprint, measured on the native
//! backend for real — parameter bytes, optimizer-state bytes (f32 vs
//! block-wise 8-bit Adam moments), and the gradient-buffer high-water
//! of the streaming per-layer fused backward — plus the Appendix-F
//! analytic overlay out to the 7B point this testbed can't train.
//!
//! Artifact-free: runs in the default build (no XLA, no Python) through
//! the `Backend` trait, and emits `BENCH_memory.json` so the repo's
//! trajectory captures bytes next to BENCH_steploop.json's tokens/sec.
//!
//!   cargo bench --bench fig3_memory -- --steps 5
//!   cargo bench --bench fig3_memory -- --configs tiny,tiny2 --methods sltrain

use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::linalg::SupportPattern;
use sltrain::data::Pipeline;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;
use sltrain::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    let a = Cli::new("fig3_memory", "Fig 3: measured native training memory + analytic overlay")
        .opt("configs", "tiny", "comma-separated native presets")
        .opt("methods", "full,lowrank,sltrain,relora,galore", "comma-separated methods")
        .opt("steps", "5", "train steps before measuring (fills the gradient peak)")
        .opt("batch", "4", "train batch rows")
        .opt("threads", "0", "step-loop worker threads (0 = auto)")
        .opt("galore-every", "0", "GaLore projector refresh period (0 = default)")
        .opt("support", "random", "sltrain support pattern: random | n:m")
        .opt("json", "BENCH_memory.json", "machine-readable output path")
        .opt("csv", "results/fig3.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps").max(1);
    let support = SupportPattern::parse(&a.str("support")).map_err(anyhow::Error::msg)?;
    let batch = a.usize("batch").max(1);

    let mut t = Table::new(
        "Fig 3 (measured) — native training state, MB",
        &[
            "config",
            "method",
            "bits",
            "params",
            "optim",
            "grad peak",
            "grad 2-phase",
            "total",
            "optim vs f32",
        ],
    );
    let mut results: Vec<Json> = Vec::new();
    for cfgn in a.str("configs").split(',') {
        let p = match preset(cfgn) {
            Some(p) => p,
            None => {
                println!("[skip] unknown preset {cfgn:?}");
                continue;
            }
        };
        for method in a.str("methods").split(',') {
            let mut f32_optim = 0u64;
            for bits in [32usize, 8] {
                let spec = BackendSpec::Native {
                    preset: p.clone(),
                    method: method.to_string(),
                    batch,
                    lr: 3e-3,
                    total_steps: 2000,
                    threads: a.usize("threads"),
                    optim_bits: bits,
                    galore_every: a.usize("galore-every"),
                    support,
                    workers: 0,
                };
                // any per-cell failure (open, init, step) skips the cell
                // so one bad combo can't abort the whole trajectory run
                let run_cell = || -> anyhow::Result<sltrain::mem::MemReport> {
                    let mut be: Box<dyn Backend> = backend::open(spec)?;
                    be.init_state(42)?;
                    let seq = be.seq_len();
                    let mut pipe = Pipeline::build(be.preset().vocab, 7);
                    for st in 0..steps {
                        let toks = pipe.train.next_batch(batch, seq);
                        be.train_step(st as i32, &toks)?;
                    }
                    Ok(be.mem_report().expect("native backend tracks memory"))
                };
                let r = match run_cell() {
                    Ok(r) => r,
                    Err(e) => {
                        println!("[skip] {cfgn}/{method} @{bits}b: {e}");
                        continue;
                    }
                };
                if bits == 32 {
                    f32_optim = r.optim_bytes;
                }
                // only measurable when the f32 leg of this combo ran
                let drop_pct = (bits == 8 && f32_optim > 0)
                    .then(|| 100.0 * (1.0 - r.optim_bytes as f64 / f32_optim as f64));
                let mb = |b: u64| fmt(b as f64 / 1e6, 3);
                t.row(vec![
                    cfgn.to_string(),
                    method.to_string(),
                    bits.to_string(),
                    mb(r.param_bytes),
                    mb(r.optim_bytes),
                    mb(r.grad_peak_bytes),
                    mb(r.grad_all_bytes),
                    mb(r.total_bytes()),
                    match drop_pct {
                        Some(d) => format!("-{d:.0}%"),
                        None => "-".into(),
                    },
                ]);
                println!(
                    "  [{cfgn}/{method} @{bits}b] optim {:.3} MB, grad peak {:.3} MB \
                     (two-phase {:.3} MB)",
                    r.optim_bytes as f64 / 1e6,
                    r.grad_peak_bytes as f64 / 1e6,
                    r.grad_all_bytes as f64 / 1e6
                );
                let mut record = vec![
                    ("config", s(cfgn)),
                    ("method", s(method)),
                    ("optim_bits", num(bits as f64)),
                    ("support", s(&support.label())),
                    ("param_bytes", num(r.param_bytes as f64)),
                    ("optim_bytes", num(r.optim_bytes as f64)),
                    ("proj_bytes", num(r.proj_bytes as f64)),
                    ("support_bytes", num(r.support_bytes as f64)),
                    ("grad_peak_bytes", num(r.grad_peak_bytes as f64)),
                    ("grad_two_phase_bytes", num(r.grad_all_bytes as f64)),
                    ("total_bytes", num(r.total_bytes() as f64)),
                ];
                // absent (not 0.0) when the f32 leg didn't run: the
                // trajectory must not record a fake 0% drop
                if let Some(d) = drop_pct {
                    record.push(("optim_drop_vs_f32_pct", num(d)));
                }
                results.push(obj(record));
            }
        }
    }
    t.print();
    t.save_csv(&a.str("csv"))?;

    // analytic overlay at the paper's scales (the Fig-3 bars themselves)
    let mut t2 = Table::new(
        "Fig 3 (analytic, paper dims) — training footprint G: params+grads+optim",
        &["size", "Adam (full)", "8-bit Adam (full)", "8-bit GaLore +pl", "8-bit SLTrain +pl", "sltrain cut"],
    );
    for size in ["paper350m", "paper1b", "spec7b"] {
        let p = preset(size).unwrap();
        let full = estimate(&p, "full", MemOptions::default()).train_bytes();
        let f8 = estimate(&p, "full", MemOptions { eight_bit: true, per_layer: false }).train_bytes();
        let g8 = estimate(&p, "galore", MemOptions { eight_bit: true, per_layer: true }).train_bytes();
        let s8 = estimate(&p, "sltrain", MemOptions { eight_bit: true, per_layer: true }).train_bytes();
        t2.row(vec![
            size.to_string(),
            fmt(MemEstimate::gb(full), 2),
            fmt(MemEstimate::gb(f8), 2),
            fmt(MemEstimate::gb(g8), 2),
            fmt(MemEstimate::gb(s8), 2),
            format!("{:.0}%", 100.0 * (1.0 - s8 / full)),
        ]);
    }
    t2.print();
    println!("\npaper shape: SLTrain cuts 51% / 58% / 73% vs Adam at 350M / 1B / 7B and\nbeats 8-bit GaLore by 17-34%; the measured table above is the same recipe\n(8-bit moments + per-layer updates) running for real in the native engine.");

    let report = obj(vec![
        ("bench", s("fig3_memory")),
        ("steps", num(steps as f64)),
        ("batch", num(batch as f64)),
        ("support", s(&support.label())),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(a.str("json"), report.to_string())?;
    println!("\n[json saved to {}]", a.str("json"));
    Ok(())
}

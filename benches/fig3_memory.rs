//! Figure 3: actual training memory footprint across model sizes and
//! algorithms — measured live state bytes (params + optimizer + consts,
//! as the runtime holds them) plus the Appendix-F analytic overlay out to
//! the 7B point this testbed can't train.
//!
//!   cargo bench --bench fig3_memory

use std::path::Path;

use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::runtime::{Artifact, Runtime};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("fig3_memory", "Fig 3 actual memory across sizes/algorithms")
        .opt("csv", "results/fig3.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;

    // measured: live training-state bytes after init, per artifact
    let mut t = Table::new(
        "Fig 3 (measured) — live training state (params+opt+supports), MB",
        &["config", "method", "state MB", "vs full"],
    );
    for cfgn in ["tiny", "tiny2"] {
        let mut full_mb = 0.0f64;
        for method in ["full", "galore", "sltrain", "sltrain_8bit"] {
            let dir = format!("artifacts/{cfgn}_{method}");
            if !Path::new(&dir).exists() {
                continue;
            }
            let mut art = Artifact::load(Path::new(&dir))?;
            let state = art.init_state(&rt, 42)?;
            // sum actual literal bytes held
            let mut bytes = 0usize;
            for lit in state.tensors.values() {
                bytes += lit.size_bytes();
            }
            let mb = bytes as f64 / 1e6;
            if method == "full" {
                full_mb = mb;
            }
            t.row(vec![
                cfgn.to_string(),
                method.to_string(),
                fmt(mb, 2),
                if full_mb > 0.0 {
                    format!("{:.0}%", 100.0 * mb / full_mb)
                } else {
                    "-".into()
                },
            ]);
        }
    }
    t.print();
    t.save_csv(&a.str("csv"))?;

    // analytic overlay at the paper's scales (the Fig-3 bars themselves)
    let mut t2 = Table::new(
        "Fig 3 (analytic, paper dims) — training footprint G: params+grads+optim",
        &["size", "Adam (full)", "8-bit Adam (full)", "8-bit GaLore +pl", "8-bit SLTrain +pl", "sltrain cut"],
    );
    for size in ["paper350m", "paper1b", "spec7b"] {
        let p = preset(size).unwrap();
        let full = estimate(&p, "full", MemOptions::default()).train_bytes();
        let f8 = estimate(&p, "full", MemOptions { eight_bit: true, per_layer: false }).train_bytes();
        let g8 = estimate(&p, "galore", MemOptions { eight_bit: true, per_layer: true }).train_bytes();
        let s8 = estimate(&p, "sltrain", MemOptions { eight_bit: true, per_layer: true }).train_bytes();
        t2.row(vec![
            size.to_string(),
            fmt(MemEstimate::gb(full), 2),
            fmt(MemEstimate::gb(f8), 2),
            fmt(MemEstimate::gb(g8), 2),
            fmt(MemEstimate::gb(s8), 2),
            format!("{:.0}%", 100.0 * (1.0 - s8 / full)),
        ]);
    }
    t2.print();
    println!("\npaper shape: SLTrain cuts 51% / 58% / 73% vs Adam at 350M / 1B / 7B and\nbeats 8-bit GaLore by 17-34%.");
    Ok(())
}

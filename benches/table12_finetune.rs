//! Table 12 (Appendix G): fine-tuning with W = W0 + BA + S (SLTrain-FT)
//! vs LoRA vs full fine-tuning.
//!
//! Substitution (DESIGN.md §3): instead of RoBERTa/GLUE we pretrain a
//! tiny LM on corpus A, then "fine-tune" on corpus B (a different
//! synthetic distribution — new seed ⇒ new vocabulary statistics and new
//! Markov chain). The paper's claim is relational: SLTrain-FT ≈ LoRA ≈
//! full FT; that relation is what this bench measures.
//!
//!   cargo bench --bench table12_finetune -- --pretrain-steps 300 --ft-steps 150

use std::path::Path;

use anyhow::Result;
use sltrain::bench::{fmt, Table};
use sltrain::coordinator::metrics::perplexity;
use sltrain::data::Pipeline;
use sltrain::runtime::{lit_f32, Artifact, Runtime, State};
use sltrain::util::cli::Cli;

const PRETRAIN_SEED: u64 = 7;
const FT_SEED: u64 = 1234; // the paper's fine-tuning seed, fittingly

fn main() -> Result<()> {
    let a = Cli::new("table12_finetune", "Table 12 fine-tuning comparison")
        .opt("pretrain-steps", "150", "pretraining steps (corpus A)")
        .opt("ft-steps", "80", "fine-tuning steps (corpus B)")
        .opt("csv", "results/table12.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;

    // 1. pretrain the base model (full-rank, corpus A)
    println!("[1/3] pretraining base model on corpus A...");
    let mut base = Artifact::load(Path::new("artifacts/tiny_full"))?;
    let mut pipe_a = Pipeline::build(base.manifest.preset.vocab, PRETRAIN_SEED);
    let mut base_state = base.init_state(&rt, 42)?;
    let batch = base.entry("train_step")?.batch;
    let seq = base.manifest.seq_len();
    for step in 0..a.usize("pretrain-steps") {
        let toks = pipe_a.train.next_batch(batch, seq);
        base.train_step(&rt, &mut base_state, step as i32, &toks)?;
    }

    // held-out set from the DOWNSTREAM corpus
    let mut pipe_b = Pipeline::build(base.manifest.preset.vocab, FT_SEED);
    let valid_b = pipe_b.valid_set(6, batch, seq);
    let zero_shot = eval_mean(&rt, &mut base, &mut base_state, &valid_b)?;
    println!("    zero-shot ppl on corpus B: {:.2}", perplexity(zero_shot));

    // snapshot pretrained weights for injection
    let pretrained: Vec<(String, Vec<usize>, Vec<f32>)> = base
        .manifest
        .params
        .iter()
        .map(|t| (t.name.clone(), t.shape.clone(), base_state.to_f32(&t.name).unwrap()))
        .collect();

    // 2. fine-tune three ways on corpus B
    println!("[2/3] fine-tuning on corpus B...");
    let mut t = Table::new(
        "Table 12 — fine-tuning on the downstream corpus",
        &["method", "ppl (corpus B)", "trainable focus"],
    );
    t.row(vec!["zero-shot (no FT)".into(), fmt(perplexity(zero_shot), 2), "-".into()]);

    // full fine-tuning: continue the full artifact on corpus B
    {
        let mut art = Artifact::load(Path::new("artifacts/tiny_full"))?;
        let mut st = art.init_state(&rt, 42)?;
        inject(&mut st, &pretrained, "w", "w")?;
        inject_rest(&mut st, &pretrained)?;
        let ppl = finetune(&rt, &mut art, &mut st, &mut pipe_b, a.usize("ft-steps"), &valid_b)?;
        t.row(vec!["Full-rank FT".into(), fmt(ppl, 2), "all params".into()]);
    }

    // LoRA FT: relora artifact (w0 frozen via trainable mask, no merges)
    for (label, dir, focus) in [
        ("LoRA FT", "artifacts/tiny_relora_ft", "B, A (+head)"),
        ("SLTrain FT", "artifacts/tiny_sltrain_ft", "B, A, vals (+head)"),
    ] {
        let p = Path::new(dir);
        if !p.exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut art = Artifact::load(p)?;
        let mut st = art.init_state(&rt, 42)?;
        // inject pretrained dense weights as the frozen W0
        inject(&mut st, &pretrained, "w", "w0")?;
        inject_rest(&mut st, &pretrained)?;
        let ppl = finetune(&rt, &mut art, &mut st, &mut pipe_b, a.usize("ft-steps"), &valid_b)?;
        t.row(vec![label.into(), fmt(ppl, 2), focus.into()]);
    }

    println!("[3/3] results");
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape (GLUE avg): full 86.28, LoRA 85.93, SLTrain-FT 85.91 — all\nwithin 0.5%; here all FT rows should land well below zero-shot and near\neach other.");
    Ok(())
}

/// Copy pretrained `layers.*.{from}` weights into `layers.*.{to}`.
fn inject(
    st: &mut State,
    pretrained: &[(String, Vec<usize>, Vec<f32>)],
    from: &str,
    to: &str,
) -> Result<()> {
    for (name, shape, data) in pretrained {
        if name.starts_with("layers.") && name.ends_with(&format!(".{from}")) {
            let target = format!("{}.{to}", name.trim_end_matches(&format!(".{from}")));
            if st.tensors.contains_key(&target) {
                st.put(&target, lit_f32(shape, data)?);
            }
        }
    }
    Ok(())
}

/// Copy embed/head/norm weights verbatim.
fn inject_rest(st: &mut State, pretrained: &[(String, Vec<usize>, Vec<f32>)]) -> Result<()> {
    for (name, shape, data) in pretrained {
        if !name.starts_with("layers.") || name.ends_with(".g") {
            if st.tensors.contains_key(name) {
                st.put(name, lit_f32(shape, data)?);
            }
        }
    }
    Ok(())
}

fn finetune(
    rt: &Runtime,
    art: &mut Artifact,
    st: &mut State,
    pipe: &mut Pipeline,
    steps: usize,
    valid: &[Vec<i32>],
) -> Result<f64> {
    let batch = art.entry("train_step")?.batch;
    let seq = art.manifest.seq_len();
    for step in 0..steps {
        let toks = pipe.train.next_batch(batch, seq);
        art.train_step(rt, st, step as i32, &toks)?;
    }
    Ok(perplexity(eval_mean(rt, art, st, valid)?))
}

fn eval_mean(
    rt: &Runtime,
    art: &mut Artifact,
    state: &mut State,
    valid: &[Vec<i32>],
) -> Result<f64> {
    let mut total = 0.0;
    for b in valid {
        total += art.eval_loss(rt, state, b)? as f64;
    }
    Ok(total / valid.len() as f64)
}

//! Table 12 (Appendix G): fine-tuning a pretrained model vs training
//! from scratch — SLTrain-FT vs LoRA-FT vs full FT.
//!
//! Artifact-free: everything runs through the `Backend` trait on the
//! pure-rust native engine (like `perf_steploop`), so CI measures it
//! from the default build with no XLA and no Python.
//!
//! Substitution (DESIGN.md §3): instead of RoBERTa/GLUE we pretrain a
//! tiny LM per method on corpus A, then fine-tune on corpus B (a
//! different synthetic distribution — new seed ⇒ new vocabulary
//! statistics and new Markov chain). Each method is fine-tuned two
//! ways:
//!
//! * **live** — continue the same parameterization (B, A, S, … keep
//!   training) with a fresh optimizer, via `TrainConfig::init_tensors`;
//! * **folded** — fold W = scale·B·A (+S / +W0) dense first
//!   (SLoPe-style), then fine-tune the dense model as `full`.
//!
//! The paper's claim is relational (GLUE avg: full 86.28, LoRA 85.93,
//! SLTrain-FT 85.91 — all within 0.5%): fine-tuned rows should land
//! well below both the zero-shot and the from-scratch-on-B baselines,
//! and near each other. That relation is what this bench measures.
//!
//!   cargo bench --bench table12_finetune -- --pretrain-steps 150 --ft-steps 80
//!   cargo bench --bench table12_finetune -- --methods sltrain,full

use sltrain::backend::{self, native::NativeBackend, Backend, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::{preset, METHODS};
use sltrain::coordinator::{train, trainer, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::linalg::SupportPattern;
use sltrain::util::cli::Cli;
use sltrain::util::json::{num, obj, s, Json};

const PRETRAIN_SEED: u64 = 7;
const FT_SEED: u64 = 1234; // the paper's fine-tuning seed, fittingly

fn main() -> anyhow::Result<()> {
    let a = Cli::new(
        "table12_finetune",
        "Table 12 fine-tuning comparison (native engine, artifact-free)",
    )
    .opt("config", "tiny", "model preset")
    .opt("methods", "full,lowrank,sltrain,relora,galore", "comma-separated methods")
    .opt("pretrain-steps", "60", "pretraining steps (corpus A)")
    .opt("ft-steps", "40", "fine-tuning steps (corpus B)")
    .opt("batch", "8", "train batch rows")
    .opt("threads", "1", "worker-pool threads (0 = auto)")
    .opt("eval-batches", "4", "held-out batches per evaluation")
    .opt("json", "BENCH_table12.json", "machine-readable output path")
    .opt("csv", "results/table12.csv", "output CSV")
    .parse_env();
    let p = preset(&a.str("config"))
        .ok_or_else(|| anyhow::anyhow!("unknown preset {:?}", a.str("config")))?;
    let batch = a.usize("batch").max(1);
    let threads = a.usize("threads");
    let pre_steps = a.usize("pretrain-steps").max(1);
    let ft_steps = a.usize("ft-steps").max(1);
    let eval_batches = a.usize("eval-batches").max(1);
    let support = SupportPattern::parse("random").map_err(anyhow::Error::msg)?;

    let spec = |method: &str| BackendSpec::Native {
        preset: p.clone(),
        method: method.to_string(),
        batch,
        lr: 3e-3,
        total_steps: 2000,
        threads,
        optim_bits: 0,
        galore_every: 0,
        support,
        workers: 0,
    };
    let cfg = |steps: usize, init: Option<Vec<sltrain::backend::StateTensor>>| TrainConfig {
        steps,
        eval_every: 0,
        eval_batches,
        log_every: 0,
        seed: 42,
        init_tensors: init,
        ..Default::default()
    };

    let mut t = Table::new(
        "Table 12 — fine-tune on corpus B after pretraining on corpus A (ppl, lower is better)",
        &["method", "zero-shot", "FT live", "FT folded", "scratch on B"],
    );
    let mut results: Vec<Json> = Vec::new();
    let methods_s = a.str("methods");
    let methods: Vec<&str> = if methods_s.is_empty() {
        METHODS.to_vec()
    } else {
        methods_s.split(',').map(str::trim).collect()
    };
    for method in methods {
        // 1. pretrain this method on corpus A
        println!("[{method}] pretraining {pre_steps} steps on corpus A...");
        let mut be = backend::open(spec(method))?;
        let mut pipe_a = Pipeline::build(be.preset().vocab, PRETRAIN_SEED);
        train(be.as_mut(), &mut pipe_a, &cfg(pre_steps, None))?;
        let seq = be.seq_len();
        // fresh-optimizer warm start: weights only, no pretrain moments
        let base: Vec<_> = be
            .state_tensors()?
            .into_iter()
            .filter(|st| !st.name.starts_with("optim."))
            .collect();

        // 2. zero-shot on corpus B (no fine-tuning at all)
        let mut pipe_b = Pipeline::build(be.preset().vocab, FT_SEED);
        let valid_b = pipe_b.valid_set(eval_batches, batch, seq);
        let zero_shot = trainer::eval(be.as_mut(), &valid_b)?;
        drop(be);

        // 3a. fine-tune LIVE: same parameterization keeps training
        println!("[{method}] fine-tuning live, {ft_steps} steps on corpus B...");
        let mut live = backend::open(spec(method))?;
        let mut pipe_live = Pipeline::build(live.preset().vocab, FT_SEED);
        let r_live = train(live.as_mut(), &mut pipe_live, &cfg(ft_steps, Some(base.clone())))?;
        drop(live);

        // 3b. fine-tune FOLDED: materialize W = scale·B·A (+S / +W0)
        // dense, then fine-tune the dense model as `full`
        println!("[{method}] folding dense + fine-tuning, {ft_steps} steps...");
        let mut conv = NativeBackend::build(
            p.clone(),
            method,
            batch,
            3e-3,
            2000,
            threads,
            0,
            0,
            support,
        )?;
        conv.init_state(42)?;
        conv.load_state_tensors(&base)?;
        conv.fold_weights()?;
        let folded = conv.state_tensors()?;
        drop(conv);
        let mut dense = backend::open(spec("full"))?;
        let mut pipe_fold = Pipeline::build(dense.preset().vocab, FT_SEED);
        let r_fold = train(dense.as_mut(), &mut pipe_fold, &cfg(ft_steps, Some(folded)))?;
        drop(dense);

        // 3c. from scratch on corpus B for the same step budget — the
        // "was pretraining worth anything" control
        let mut scratch = backend::open(spec(method))?;
        let mut pipe_scr = Pipeline::build(scratch.preset().vocab, FT_SEED);
        let r_scr = train(scratch.as_mut(), &mut pipe_scr, &cfg(ft_steps, None))?;
        drop(scratch);

        t.row(vec![
            method.to_string(),
            fmt(zero_shot.exp(), 2),
            fmt(r_live.final_ppl, 2),
            fmt(r_fold.final_ppl, 2),
            fmt(r_scr.final_ppl, 2),
        ]);
        println!(
            "  [{method}] zero-shot {:.2} | live {:.2} | folded {:.2} | scratch {:.2}",
            zero_shot.exp(),
            r_live.final_ppl,
            r_fold.final_ppl,
            r_scr.final_ppl
        );
        results.push(obj(vec![
            ("config", s(&p.name)),
            ("method", s(method)),
            ("zero_shot_loss", num(zero_shot)),
            ("zero_shot_ppl", num(zero_shot.exp())),
            ("ft_live_loss", num(r_live.final_eval_loss)),
            ("ft_live_ppl", num(r_live.final_ppl)),
            ("ft_fold_loss", num(r_fold.final_eval_loss)),
            ("ft_fold_ppl", num(r_fold.final_ppl)),
            ("scratch_loss", num(r_scr.final_eval_loss)),
            ("scratch_ppl", num(r_scr.final_ppl)),
        ]));
    }

    t.print();
    t.save_csv(&a.str("csv"))?;
    let report = obj(vec![
        ("bench", s("table12_finetune")),
        ("config", s(&p.name)),
        ("pretrain_steps", num(pre_steps as f64)),
        ("ft_steps", num(ft_steps as f64)),
        ("batch", num(batch as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(a.str("json"), report.to_string())?;
    println!("\n[json saved to {}]", a.str("json"));
    println!(
        "paper shape (GLUE avg): full 86.28, LoRA 85.93, SLTrain-FT 85.91 — all\n\
         within 0.5%; here every FT column should land below zero-shot, and the\n\
         live and folded columns should track each other per method."
    );
    Ok(())
}

//! Tables 8/9/10 (Appendix F): the detailed memory breakdown for every
//! method at the paper's ACTUAL model dimensions, plus the SLTrain
//! (r, δ) variants. Pure estimator — cross-checked against the paper's
//! published numbers in the mem module's unit tests.
//!
//!   cargo bench --bench table8_mem_breakdown

use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::mem::{breakdown_row, estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table8_mem_breakdown", "Appendix-F memory breakdowns")
        .opt("csv", "results/table8.csv", "output CSV")
        .parse_env();

    // Table 8: Param / Optim per method per size
    let mut t = Table::new(
        "Table 8 — memory breakdown (Param G / Optim G), paper dims",
        &["size", "full", "lowrank", "relora", "galore", "sltrain"],
    );
    for size in ["paper60m", "paper130m", "paper350m", "paper1b"] {
        let p = preset(size).unwrap();
        let mut row = vec![size.to_string()];
        for m in ["full", "lowrank", "relora", "galore", "sltrain"] {
            let e = estimate(&p, m, MemOptions::default());
            row.push(format!(
                "{}/{}",
                fmt(MemEstimate::gb(e.param_bytes), 2),
                fmt(MemEstimate::gb(e.optim_bytes), 2)
            ));
        }
        t.row(row);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper Table 8 reference row (60M):  full 0.12/0.23  lowrank 0.08/0.16");
    println!("  relora 0.20/0.17  galore 0.12/0.16  sltrain 0.09/0.17");

    // Tables 9/10 style: full component breakdown per method
    for size in ["paper60m", "paper130m"] {
        let p = preset(size).unwrap();
        println!("\n== {} component breakdown (Tables 9/10 style) ==", size);
        for m in ["full", "lowrank", "relora", "galore", "sltrain"] {
            println!("  {}", breakdown_row(&p, m, MemOptions::default()));
        }
    }

    // SLTrain r/delta variants at 60M (Table 9's columns)
    let mut t9 = Table::new(
        "Table 9 — SLTrain 60M memory vs (r, delta)",
        &["variant", "total params(M)", "sparse(M)", "param mem(G)", "optim mem(G)", "total(G)"],
    );
    let base = preset("paper60m").unwrap();
    for (r, d) in [(128usize, 0.01f64), (128, 0.05), (96, 0.03), (160, 0.03), (128, 0.03)] {
        let mut p = base.clone();
        p.rank = r;
        p.delta = d;
        let e = estimate(&p, "sltrain", MemOptions::default());
        t9.row(vec![
            format!("r={r}, d={d}"),
            fmt(e.total_params() / 1e6, 2),
            fmt(e.sparse_params / 1e6, 2),
            fmt(MemEstimate::gb(e.param_bytes), 2),
            fmt(MemEstimate::gb(e.optim_bytes), 2),
            fmt(MemEstimate::gb(e.table2_bytes()), 2),
        ]);
    }
    t9.print();
    println!("\npaper Table 9: r=128,d=0.01 -> 43.02M/0.26G ... r=160,d=0.03 -> 46.03M/0.28G");
    Ok(())
}

//! Tables 8/9/10 (Appendix F): the detailed memory breakdown for every
//! method at the paper's ACTUAL model dimensions, plus the SLTrain
//! (r, δ) variants. Pure estimator — cross-checked against the paper's
//! published numbers in the mem module's unit tests.
//!
//!   cargo bench --bench table8_mem_breakdown
//!
//! The estimator tables are cross-checked against a *measured* section
//! at the end: the native backend's `mem_report` (bytes actually held)
//! for f32 vs block-wise 8-bit Adam moments on the tiny preset.

use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::linalg::SupportPattern;
use sltrain::data::Pipeline;
use sltrain::mem::{breakdown_row, estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table8_mem_breakdown", "Appendix-F memory breakdowns")
        .opt("csv", "results/table8.csv", "output CSV")
        .parse_env();

    // Table 8: Param / Optim per method per size
    let mut t = Table::new(
        "Table 8 — memory breakdown (Param G / Optim G), paper dims",
        &["size", "full", "lowrank", "relora", "galore", "sltrain"],
    );
    for size in ["paper60m", "paper130m", "paper350m", "paper1b"] {
        let p = preset(size).unwrap();
        let mut row = vec![size.to_string()];
        for m in ["full", "lowrank", "relora", "galore", "sltrain"] {
            let e = estimate(&p, m, MemOptions::default());
            row.push(format!(
                "{}/{}",
                fmt(MemEstimate::gb(e.param_bytes), 2),
                fmt(MemEstimate::gb(e.optim_bytes), 2)
            ));
        }
        t.row(row);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper Table 8 reference row (60M):  full 0.12/0.23  lowrank 0.08/0.16");
    println!("  relora 0.20/0.17  galore 0.12/0.16  sltrain 0.09/0.17");

    // Tables 9/10 style: full component breakdown per method
    for size in ["paper60m", "paper130m"] {
        let p = preset(size).unwrap();
        println!("\n== {} component breakdown (Tables 9/10 style) ==", size);
        for m in ["full", "lowrank", "relora", "galore", "sltrain"] {
            println!("  {}", breakdown_row(&p, m, MemOptions::default()));
        }
    }

    // SLTrain r/delta variants at 60M (Table 9's columns)
    let mut t9 = Table::new(
        "Table 9 — SLTrain 60M memory vs (r, delta)",
        &["variant", "total params(M)", "sparse(M)", "param mem(G)", "optim mem(G)", "total(G)"],
    );
    let base = preset("paper60m").unwrap();
    for (r, d) in [(128usize, 0.01f64), (128, 0.05), (96, 0.03), (160, 0.03), (128, 0.03)] {
        let mut p = base.clone();
        p.rank = r;
        p.delta = d;
        let e = estimate(&p, "sltrain", MemOptions::default());
        t9.row(vec![
            format!("r={r}, d={d}"),
            fmt(e.total_params() / 1e6, 2),
            fmt(e.sparse_params / 1e6, 2),
            fmt(MemEstimate::gb(e.param_bytes), 2),
            fmt(MemEstimate::gb(e.optim_bytes), 2),
            fmt(MemEstimate::gb(e.table2_bytes()), 2),
        ]);
    }
    t9.print();
    println!("\npaper Table 9: r=128,d=0.01 -> 43.02M/0.26G ... r=160,d=0.03 -> 46.03M/0.28G");

    // Measured (native backend, tiny preset): the bytes the engine
    // actually holds after one step — the estimator's optimizer column
    // made concrete, f32 vs block-wise 8-bit moments, plus the
    // streaming backward's gradient high-water.
    let mut tm = Table::new(
        "Table 8 (measured) — native tiny: optimizer bytes f32 vs 8-bit, MB",
        &["method", "optim f32", "optim 8-bit", "drop", "grad peak", "grad 2-phase"],
    );
    for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
        let mut optim = [0u64; 2];
        let mut grad_peak = 0u64;
        let mut grad_all = 0u64;
        for (i, bits) in [32usize, 8].into_iter().enumerate() {
            let spec = BackendSpec::Native {
                preset: preset("tiny").unwrap(),
                method: method.to_string(),
                batch: 2,
                lr: 3e-3,
                total_steps: 100,
                threads: 1,
                optim_bits: bits,
                galore_every: 0,
                support: SupportPattern::UniformRandom,
                workers: 0,
            };
            let mut be: Box<dyn Backend> = backend::open(spec)?;
            be.init_state(42)?;
            let mut pipe = Pipeline::build(be.preset().vocab, 7);
            let toks = pipe.train.next_batch(2, be.seq_len());
            be.train_step(0, &toks)?;
            let r = be.mem_report().expect("native backend tracks memory");
            optim[i] = r.optim_bytes;
            grad_peak = r.grad_peak_bytes;
            grad_all = r.grad_all_bytes;
        }
        tm.row(vec![
            method.to_string(),
            fmt(optim[0] as f64 / 1e6, 3),
            fmt(optim[1] as f64 / 1e6, 3),
            format!("{:.0}%", 100.0 * (1.0 - optim[1] as f64 / optim[0] as f64)),
            fmt(grad_peak as f64 / 1e6, 3),
            fmt(grad_all as f64 / 1e6, 3),
        ]);
    }
    tm.print();
    Ok(())
}

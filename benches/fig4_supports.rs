//! Figure 4: convergence with five different random sparse supports.
//! Paper shape: the curves coincide — support choice does not matter.
//!
//!   cargo bench --bench fig4_supports -- --steps 150

use std::path::Path;

use sltrain::backend::xla_backend::XlaBackend;
use sltrain::backend::Backend;
use sltrain::bench::{fmt, Table};
use sltrain::coordinator::metrics::stats;
use sltrain::coordinator::{train, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("fig4_supports", "Fig 4 random-support convergence")
        .opt("steps", "80", "steps per run")
        .opt("csv", "results/fig4.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps");

    let mut curves = vec![];
    let mut finals = vec![];
    for seed in 1..=5 {
        let dir = format!("artifacts/tiny_sltrain_sup{seed}");
        if !Path::new(&dir).exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut be = XlaBackend::open(Path::new(&dir))?;
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        let cfg = TrainConfig {
            steps,
            eval_every: (steps / 5).max(1),
            eval_batches: 4,
            log_every: 0,
            ..Default::default()
        };
        let r = train(&mut be, &mut pipe, &cfg)?;
        println!("  support seed {seed}: final ppl {:.2}", r.final_ppl);
        finals.push(r.final_ppl);
        curves.push((seed, r.eval_curve));
    }
    anyhow::ensure!(!curves.is_empty(), "no tiny_sltrain_sup* artifacts (make bench-artifacts)");

    let mut t = Table::new(
        "Fig 4 — eval ppl vs step across five random supports",
        &["step", "sup1", "sup2", "sup3", "sup4", "sup5"],
    );
    for i in 0..curves[0].1.points.len() {
        let step = curves[0].1.points[i].0;
        let mut row = vec![step.to_string()];
        for (_, c) in &curves {
            row.push(
                c.points
                    .get(i)
                    .map(|&(_, l)| fmt(l.exp(), 2))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        while row.len() < 6 {
            row.push("-".into());
        }
        t.row(row);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;

    let s = stats(&finals);
    println!(
        "\nfinal ppl: mean {:.2} ± {:.2} ({:.1}% rel. spread)\npaper shape: curves indistinguishable — random support choice immaterial.",
        s.mean,
        s.std,
        100.0 * s.std / s.mean
    );
    Ok(())
}

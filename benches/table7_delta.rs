//! Table 7: pushing δ up to 0.1 closes the gap to full-rank while still
//! saving ~45% of the parameters. Paper shape: ppl(δ=0.1) ≈ ppl(full),
//! parameter saving shrinks only mildly as δ grows.
//!
//!   cargo bench --bench table7_delta -- --steps 300

use std::path::Path;

use sltrain::backend::xla_backend::XlaBackend;
use sltrain::bench::{fmt, Table};
use sltrain::coordinator::trainer::quick_train;
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table7_delta", "Table 7 delta sweep vs full-rank")
        .opt("steps", "120", "train steps per cell")
        .opt("csv", "results/table7.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps");

    let cells: Vec<(&str, &str)> = vec![
        ("artifacts/tiny2_full", "Full-Rank"),
        ("artifacts/tiny2_sltrain", "SLTrain (d=0.03)"),
        ("artifacts/tiny2_sltrain_d005", "SLTrain (d=0.05)"),
        ("artifacts/tiny2_sltrain_d010", "SLTrain (d=0.10)"),
    ];
    let mut full_params = 0f64;
    let mut t = Table::new(
        &format!("Table 7 — delta sweep, tiny2, {steps} steps"),
        &["setting", "ppl", "param(M)", "vs full params"],
    );
    for (dir, label) in cells {
        if !Path::new(dir).exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut be = XlaBackend::open(Path::new(dir))?;
        let r = quick_train(&mut be, steps, 7)?;
        let params_m = r.n_params as f64 / 1e6;
        if label == "Full-Rank" {
            full_params = params_m;
        }
        t.row(vec![
            label.to_string(),
            fmt(r.final_ppl, 2),
            fmt(params_m, 3),
            if full_params > 0.0 {
                format!("{:+.0}%", 100.0 * (params_m / full_params - 1.0))
            } else {
                "-".into()
            },
        ]);
        println!("  [{label}] ppl {:.2}", r.final_ppl);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: delta=0.1 matches or beats full-rank ppl (18.72 vs 18.80 at\n350M) while keeping a ~42-45% parameter cut.");
    Ok(())
}

//! Table 3: training throughput (tokens/sec) across all five methods.
//! Paper shape: SLTrain within a few % of full-rank (its cost is the
//! sparse scatter/gather), GaLore ≈ full-rank off refresh steps (the
//! periodic projector SVD is amortized), lowrank/relora fastest.
//!
//! Engine-agnostic: the native backend (default) measures the pure-rust
//! step loop with no artifacts; `--backend xla` measures the AOT/PJRT
//! path (needs the `xla` cargo feature and `make artifacts`).
//!
//!   cargo bench --bench table3_throughput -- --steps 30
//!   cargo bench --bench table3_throughput --features xla -- --backend xla

use std::path::Path;

use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::preset;
use sltrain::data::Pipeline;
use sltrain::linalg::SupportPattern;
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table3_throughput", "Table 3 training throughput")
        .opt("backend", "native", "engine: native | xla")
        .opt("steps", "30", "measured steps (after 3 warmup)")
        .opt("config", "tiny", "scale point")
        .opt("threads", "0", "native step-loop worker threads (0 = auto)")
        .opt("optim-bits", "0", "native Adam moment precision: 32 | 8 (0 = auto)")
        .opt("galore-every", "0", "native GaLore projector refresh period (0 = default)")
        .opt("support", "random", "native sltrain support pattern: random | n:m")
        .opt("csv", "results/table3.csv", "output CSV")
        .parse_env();
    let cfgn = a.str("config");
    let engine = a.str("backend");
    let support = SupportPattern::parse(&a.str("support")).map_err(anyhow::Error::msg)?;

    let mut t = Table::new(
        &format!("Table 3 — tokens/sec, {} ({} backend)", cfgn, engine),
        &["method", "tok/s", "rel. to full", "step ms"],
    );
    let mut full_tps = 0.0f64;
    for method in ["full", "lowrank", "relora", "galore", "sltrain"] {
        let spec = match engine.as_str() {
            "xla" => {
                let dir = format!("artifacts/{cfgn}_{method}");
                if !Path::new(&dir).exists() {
                    println!("[skip] {dir}");
                    continue;
                }
                BackendSpec::Xla { artifact_dir: dir.into() }
            }
            _ => {
                let p = preset(&cfgn)
                    .ok_or_else(|| anyhow::anyhow!("unknown preset {cfgn:?}"))?;
                BackendSpec::Native {
                    preset: p,
                    method: method.to_string(),
                    batch: 8,
                    lr: 3e-3,
                    total_steps: 2000,
                    threads: a.usize("threads"),
                    optim_bits: a.usize("optim-bits"),
                    galore_every: a.usize("galore-every"),
                    support,
                    workers: 0,
                }
            }
        };
        let mut be: Box<dyn Backend> = backend::open(spec)?;
        be.init_state(42)?;
        let batch = be.batch_size();
        let seq = be.seq_len();
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        for w in 0..3 {
            let toks = pipe.train.next_batch(batch, seq);
            be.train_step(w, &toks)?;
        }
        let steps = a.usize("steps");
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let toks = pipe.train.next_batch(batch, seq);
            be.train_step(3 + s as i32, &toks)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let tps = (steps * batch * seq) as f64 / dt;
        if method == "full" {
            full_tps = tps;
        }
        let rel = if full_tps > 0.0 { tps / full_tps } else { 1.0 };
        t.row(vec![
            method.to_string(),
            fmt(tps, 0),
            fmt(rel, 3),
            fmt(dt / steps as f64 * 1e3, 1),
        ]);
        println!("  [{method}] {tps:.0} tok/s");
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: SLTrain 0.94-0.99x of full-rank (350M: 30293 vs 32072).");
    Ok(())
}

//! Table 3: training throughput (tokens/sec) — SLTrain vs Full-Rank vs
//! GaLore. Paper shape: SLTrain within a few % of full-rank (its cost is
//! the sparse scatter/gather), GaLore ≈ full-rank.
//!
//!   cargo bench --bench table3_throughput -- --steps 30

use std::path::Path;

use sltrain::bench::{fmt, Table};
use sltrain::data::Pipeline;
use sltrain::runtime::{Artifact, Runtime};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table3_throughput", "Table 3 training throughput")
        .opt("steps", "30", "measured steps (after 3 warmup)")
        .opt("config", "tiny", "scale point")
        .opt("csv", "results/table3.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;
    let cfgn = a.str("config");

    let mut t = Table::new(
        &format!("Table 3 — tokens/sec, {} (CPU PJRT)", cfgn),
        &["method", "tok/s", "rel. to full", "step ms"],
    );
    let mut full_tps = 0.0f64;
    for method in ["full", "galore", "sltrain"] {
        let dir = format!("artifacts/{cfgn}_{method}");
        if !Path::new(&dir).exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut art = Artifact::load(Path::new(&dir))?;
        let batch = art.entry("train_step")?.batch;
        let seq = art.manifest.seq_len();
        let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
        let mut state = art.init_state(&rt, 42)?;
        for w in 0..3 {
            let toks = pipe.train.next_batch(batch, seq);
            art.train_step(&rt, &mut state, w, &toks)?;
        }
        let steps = a.usize("steps");
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let toks = pipe.train.next_batch(batch, seq);
            art.train_step(&rt, &mut state, 3 + s as i32, &toks)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let tps = (steps * batch * seq) as f64 / dt;
        if method == "full" {
            full_tps = tps;
        }
        let rel = if full_tps > 0.0 { tps / full_tps } else { 1.0 };
        t.row(vec![
            method.to_string(),
            fmt(tps, 0),
            fmt(rel, 3),
            fmt(dt / steps as f64 * 1e3, 1),
        ]);
        println!("  [{method}] {tps:.0} tok/s");
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: SLTrain 0.94-0.99x of full-rank (350M: 30293 vs 32072).");
    Ok(())
}

"""L2: the LLaMA-family model under all five weight parameterizations.

`build(cfg, method)` returns a `ModelDef`: ordered parameter specs (the
contract the rust runtime programs against via manifest.json), an init
function, fixed sparse supports (sltrain), and pure functions for
forward / loss. Everything here is build-time only: `aot.py` lowers the
jitted functions to HLO text once, and rust executes them forever after.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .configs import ModelConfig
from .kernels import ref


@dataclass
class ModelDef:
    cfg: ModelConfig
    method: str
    # ordered (name, shape, kind) — kind: param | const
    specs: list
    supports: dict  # name -> np.int32 flat support (sltrain only)
    trainable: list  # param names receiving gradients
    init_fn: Callable  # (key) -> params dict
    apply_fn: Callable  # (params, consts, tokens) -> logits
    loss_fn: Callable  # (params, consts, tokens) -> scalar mean CE

    @property
    def param_names(self):
        return [n for n, _, k in self.specs if k == "param"]

    @property
    def const_names(self):
        return [n for n, _, k in self.specs if k == "const"]

    def shape_of(self, name):
        return dict((n, s) for n, s, _ in self.specs)[name]

    def n_params(self):
        return sum(int(np.prod(s)) for n, s, k in self.specs if k == "param")


def _linear_paths(cfg: ModelConfig):
    """All adapted linears as (path, d_in, d_out)."""
    out = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        if cfg.adapt_attn:
            for nm in ("q", "k", "v", "o"):
                out.append((f"{p}.attn.{nm}", cfg.d_model, cfg.d_model))
        if cfg.adapt_mlp:
            out.append((f"{p}.mlp.gate", cfg.d_model, cfg.d_ff))
            out.append((f"{p}.mlp.up", cfg.d_model, cfg.d_ff))
            out.append((f"{p}.mlp.down", cfg.d_ff, cfg.d_model))
    return out


def build(cfg: ModelConfig, method: str, support_seed: int = 42,
          use_pallas: bool = False) -> ModelDef:
    specs, supports = [], {}
    # embeddings / head / norms are always full-rank trainable (paper §5.1:
    # "the remaining parameters are updated with full-rank")
    specs.append(("embed.w", (cfg.vocab, cfg.d_model), "param"))
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        specs.append((f"{p}.ln1.g", (cfg.d_model,), "param"))
        specs.append((f"{p}.ln2.g", (cfg.d_model,), "param"))
    specs.append(("lnf.g", (cfg.d_model,), "param"))
    specs.append(("head.w", (cfg.d_model, cfg.vocab), "param"))

    for j, (path, d_in, d_out) in enumerate(_linear_paths(cfg)):
        for s in layers.linear_param_specs(method, path, d_in, d_out, cfg.rank, cfg.delta):
            specs.append(s)
        if method in ("sltrain", "sltrain_ft"):
            # fixed uniform support, one independent seed per linear
            supports[f"{path}.idx"] = ref.random_support(
                support_seed * 100003 + j, d_in, d_out, cfg.delta
            )

    specs.sort(key=lambda s: s[0])
    # relora: w0 is updated only through the merge artifact, not by grads
    trainable = [n for n, _, k in specs if k == "param" and not n.endswith(".w0")]

    def init_fn(key):
        params = {}
        keys = jax.random.split(key, 4 + len(_linear_paths(cfg)))
        params["embed.w"] = (
            jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        )
        params["head.w"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab), jnp.float32)
            * jnp.sqrt(2.0 / cfg.d_model)
        )
        params["lnf.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        for i in range(cfg.n_layers):
            params[f"layers.{i}.ln1.g"] = jnp.ones((cfg.d_model,), jnp.float32)
            params[f"layers.{i}.ln2.g"] = jnp.ones((cfg.d_model,), jnp.float32)
        for j, (path, d_in, d_out) in enumerate(_linear_paths(cfg)):
            params.update(
                layers.init_linear(
                    method, path, d_in, d_out, cfg.rank, cfg.delta, keys[4 + j]
                )
            )
        return params

    cos, sin = layers.rope_tables(cfg.seq_len, cfg.head_dim, cfg.rope_theta)

    def apply_fn(params, consts, tokens):
        """tokens: i32[b, s] -> logits f32[b, s, vocab]."""
        x = jnp.take(params["embed.w"], tokens, axis=0)
        s = tokens.shape[1]
        c, sn = cos[:s], sin[:s]
        for i in range(cfg.n_layers):
            x = layers.block(
                method, params, consts, f"layers.{i}", x, cfg, c, sn, use_pallas
            )
        x = layers.rmsnorm(x, params["lnf.g"])
        return x @ params["head.w"]

    def loss_fn(params, consts, tokens):
        """Mean next-token cross-entropy (the paper's pretraining loss)."""
        logits = apply_fn(params, consts, tokens[:, :-1])
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    return ModelDef(cfg, method, specs, supports, trainable, init_fn, apply_fn, loss_fn)


def make_relora_merge(cfg: ModelConfig):
    """The ReLoRA restart (eq. 1): W0 <- W0 + scale*BA; B <- 0; A <- kaiming.

    Lowered as its own artifact and invoked by the L3 restart scheduler
    every T steps. The optimizer-state reset for (B, A) happens rust-side
    (zeroing buffers), matching ReLoRA's "reinitialize the optimizer".
    """

    def merge(params, seed):
        out = dict(params)
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        for j, (path, _, _) in enumerate(_linear_paths(cfg)):
            B, A = params[f"{path}.B"], params[f"{path}.A"]
            out[f"{path}.w0"] = params[f"{path}.w0"] + cfg.scale * (B @ A)
            out[f"{path}.B"] = jnp.zeros_like(B)
            k = jax.random.fold_in(key, j)
            out[f"{path}.A"] = jax.random.normal(k, A.shape, jnp.float32) * jnp.sqrt(
                2.0 / A.shape[0]
            )
        return out

    return merge


def sl_from_dense(W, idx, rank: int, mode: str = "resid"):
    """Table-1 utility: best rank-r approx of a dense pretrained W plus the
    residual gathered at `idx` (build-time host SVD). Returns (B, A, vals).

    mode='resid' -> vals are the residual entries at idx (pruning rows of
    Table 1); mode='zero' -> vals start at 0 (the "sparse training" rows).
    """
    U, S, Vt = np.linalg.svd(np.asarray(W), full_matrices=False)
    B = U[:, :rank] * S[:rank]
    A = Vt[:rank]
    if mode == "zero":
        vals = np.zeros(len(idx), np.float32)
    else:
        resid = np.asarray(W) - B @ A
        vals = resid.reshape(-1)[np.asarray(idx)].astype(np.float32)
    return B.astype(np.float32), A.astype(np.float32), vals

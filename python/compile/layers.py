"""LLaMA building blocks with pluggable weight parameterizations.

Every linear layer in the transformer goes through `linear()`, which
dispatches on the method under reproduction:

  full     W                      (vanilla Adam baseline)
  lowrank  scale * B A            (Kamalakara et al. [24])
  sltrain  scale * B A ⊕_idx V    (the paper, Algorithm 1)
  relora   W0 + scale * B A       (Lialin et al. [32]; W0 merged by L3)
  galore   W                      (Zhao et al. [59]; projection in optim)

The model is purely functional: parameters live in a flat
``dict[str, Array]``, fixed sparse supports in a parallel ``consts``
dict (fed by the rust runtime from sidecar files). Names are
dot-paths, e.g. ``layers.3.attn.q.B``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels import sl_linear as slk


# ------------------------------------------------------------------ linears


def linear(method, params, consts, path, x, scale, use_pallas=False):
    """Apply the `path` linear to x [..., d_in] under `method`."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if method in ("full", "galore"):
        y = x2 @ params[f"{path}.w"]
    elif method == "lowrank":
        y = ref.lowrank_linear(x2, params[f"{path}.B"], params[f"{path}.A"], scale)
    elif method == "relora":
        y = x2 @ params[f"{path}.w0"] + ref.lowrank_linear(
            x2, params[f"{path}.B"], params[f"{path}.A"], scale
        )
    elif method == "sltrain_ft":
        # Appendix G fine-tuning: W = W0 + BA + S, W0 frozen
        y = x2 @ params[f"{path}.w0"] + ref.sl_linear(
            x2, params[f"{path}.B"], params[f"{path}.A"],
            consts[f"{path}.idx"], params[f"{path}.vals"], scale,
        )
    elif method == "sltrain":
        B, A, vals = params[f"{path}.B"], params[f"{path}.A"], params[f"{path}.vals"]
        if use_pallas:
            # static support: baked into the kernel at trace time
            idx = np.asarray(consts[f"{path}.idx"])
            f = slk.make_sl_linear(idx, A.shape[1], scale, use_pallas=True)
            y = f(x2, B, A, vals)
        else:
            y = ref.sl_linear(x2, B, A, consts[f"{path}.idx"], vals, scale)
    else:
        raise ValueError(f"unknown method {method}")
    return y.reshape(*lead, y.shape[-1])


def linear_param_specs(method, path, d_in, d_out, rank, delta):
    """(name, shape, kind) for one linear. kind: param | const."""
    if method in ("full", "galore"):
        return [(f"{path}.w", (d_in, d_out), "param")]
    if method in ("lowrank", "relora"):
        specs = [(f"{path}.B", (d_in, rank), "param"), (f"{path}.A", (rank, d_out), "param")]
        if method == "relora":
            specs.insert(0, (f"{path}.w0", (d_in, d_out), "param"))
        return specs
    if method in ("sltrain", "sltrain_ft"):
        nnz = max(1, int(round(delta * d_in * d_out)))
        specs = [
            (f"{path}.B", (d_in, rank), "param"),
            (f"{path}.A", (rank, d_out), "param"),
            (f"{path}.vals", (nnz,), "param"),
            (f"{path}.idx", (nnz,), "const"),
        ]
        if method == "sltrain_ft":
            specs.insert(0, (f"{path}.w0", (d_in, d_out), "param"))
        return specs
    raise ValueError(method)


def init_linear(method, path, d_in, d_out, rank, delta, key):
    """Paper §3.3 init: Kaiming for A (and full W), zeros for B, uniform
    [-1/sqrt(d_in), 1/sqrt(d_in)] for sparse values."""
    out = {}
    k1, k2, k3 = jax.random.split(key, 3)
    kaiming = lambda k, shape: jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(
        2.0 / shape[0]
    )
    if method in ("full", "galore"):
        out[f"{path}.w"] = kaiming(k1, (d_in, d_out))
        return out
    if method in ("relora", "sltrain_ft"):
        out[f"{path}.w0"] = kaiming(k3, (d_in, d_out))
    if method in ("lowrank", "relora", "sltrain", "sltrain_ft"):
        out[f"{path}.B"] = jnp.zeros((d_in, rank), jnp.float32)
        out[f"{path}.A"] = kaiming(k1, (rank, d_out))
        if method == "lowrank":
            # pure low-rank training cannot start at BA=0 (no gradient to
            # escape); use Kaiming B as in [24]
            out[f"{path}.B"] = kaiming(k2, (d_in, rank))
    if method in ("sltrain", "sltrain_ft"):
        nnz = max(1, int(round(delta * d_in * d_out)))
        bound = 1.0 / jnp.sqrt(d_in)
        out[f"{path}.vals"] = jax.random.uniform(
            k2, (nnz,), jnp.float32, -bound, bound
        )
    return out


# ------------------------------------------------------------------ blocks


def rmsnorm(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope_tables(seq_len, head_dim, theta):
    pos = np.arange(seq_len, dtype=np.float32)
    freqs = theta ** (-np.arange(0, head_dim, 2, dtype=np.float32) / head_dim)
    ang = pos[:, None] * freqs[None, :]  # [s, hd/2]
    return jnp.asarray(np.cos(ang)), jnp.asarray(np.sin(ang))


def apply_rope(x, cos, sin):
    """x: [b, s, h, hd] — rotate pairs (standard LLaMA RoPE)."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[None, :, None, :], sin[None, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def attention(method, params, consts, path, x, cfg, cos, sin, use_pallas=False):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    scale = cfg.scale if cfg.adapt_attn else 1.0
    m = method if cfg.adapt_attn else "full"
    q = linear(m, params, consts, f"{path}.q", x, scale, use_pallas)
    k = linear(m, params, consts, f"{path}.k", x, scale, use_pallas)
    v = linear(m, params, consts, f"{path}.v", x, scale, use_pallas)
    q = apply_rope(q.reshape(b, s, h, hd), cos, sin)
    k = apply_rope(k.reshape(b, s, h, hd), cos, sin)
    v = v.reshape(b, s, h, hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, jnp.finfo(x.dtype).min)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
    return linear(m, params, consts, f"{path}.o", o, scale, use_pallas)


def mlp(method, params, consts, path, x, cfg, use_pallas=False):
    scale = cfg.scale if cfg.adapt_mlp else 1.0
    m = method if cfg.adapt_mlp else "full"
    g = linear(m, params, consts, f"{path}.gate", x, scale, use_pallas)
    u = linear(m, params, consts, f"{path}.up", x, scale, use_pallas)
    h = jax.nn.silu(g) * u  # SwiGLU [44]
    return linear(m, params, consts, f"{path}.down", h, scale, use_pallas)


def block(method, params, consts, path, x, cfg, cos, sin, use_pallas=False):
    # pre-normalization (LLaMA)
    h = x + attention(
        method, params, consts, f"{path}.attn",
        rmsnorm(x, params[f"{path}.ln1.g"]), cfg, cos, sin, use_pallas,
    )
    return h + mlp(
        method, params, consts, f"{path}.mlp",
        rmsnorm(h, params[f"{path}.ln2.g"]), cfg, use_pallas,
    )

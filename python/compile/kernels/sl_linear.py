"""Pallas kernels for the SLTrain linear layer (Algorithm 1).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
CUDA implementation scatter-adds the sparse values into the dense ``BA``
product in HBM. On TPU there are no HBM atomics; instead we exploit that
the support is FIXED at init (the paper's central trick) and bucket the
nnz entries by weight tile *at trace time*. Each grid step then:

  1. computes its ``(bd, bp)`` tile of ``scale * B@A`` on the MXU
     (``bd×r @ r×bp`` — both factors VMEM-resident for r ≤ 512),
  2. scatter-adds its statically-padded segment of sparse values into the
     VMEM tile (static-bound loop, no dynamic shapes),
  3. either writes the tile out (``sl_densify``) or contracts it with the
     activation tile immediately (``sl_matmul`` — the fused path, where
     the densified W never round-trips to HBM at all).

All kernels run under ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md). Correctness is pinned to ``ref.py`` by
pytest; TPU efficiency is argued structurally in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Default tile sizes. 128 aligns with the MXU systolic array; small
# shapes in tests shrink these via _tile().
DEF_BD = 128
DEF_BP = 128
DEF_BM = 128


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def bucket_support(idx: np.ndarray, p: int, bd: int, bp: int, gd: int, gp: int):
    """Trace-time bucketing of the fixed support by (bd, bp) weight tile.

    ``idx`` is flat row-major into the ORIGINAL [d, p] matrix; ``gd, gp``
    describe the (possibly padded) tile grid. Returns
    (tile_local, tile_gather, cap) where, with ``nt = gd*gp`` tiles in
    row-major tile order:
      tile_local  : [nt, cap] int32 — flat index *within* the tile
                    (row_local * bp + col_local), padded with -1
      tile_gather : [nt, cap] int32 — position into ``vals`` to gather the
                    runtime value from, padded with 0 (masked by -1s)
      cap         : python int — max segment length over tiles (static)

    This is pure numpy on the static support, so the result is a constant
    folded into the lowered HLO — exactly the paper's "store only indices
    and values" with the indices compiled away.
    """
    idx = np.asarray(idx)
    rows, cols = idx // p, idx % p
    tid = (rows // bd) * gp + (cols // bp)
    local = (rows % bd) * bp + (cols % bp)
    nt = gd * gp
    order = np.argsort(tid, kind="stable")
    tid_s, local_s = tid[order], local[order]
    counts = np.bincount(tid_s, minlength=nt)
    cap = max(1, int(counts.max()) if len(idx) else 1)
    tile_local = np.full((nt, cap), -1, dtype=np.int32)
    tile_gather = np.zeros((nt, cap), dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for t in range(nt):
        s, c = starts[t], counts[t]
        tile_local[t, :c] = local_s[s : s + c]
        tile_gather[t, :c] = order[s : s + c]
    return tile_local, tile_gather, cap


def _densify_kernel(B_ref, A_ref, tl_ref, tv_ref, o_ref, *, scale, bp):
    """One (bd, bp) tile: MXU product + static-capacity sparse scatter."""
    w = scale * jnp.dot(B_ref[...], A_ref[...], preferred_element_type=jnp.float32)
    tl = tl_ref[...].reshape(-1)  # [cap] local flat idx, -1 padded
    tv = tv_ref[...].reshape(-1)  # [cap] gathered values
    add = jnp.where(tl >= 0, tv, 0.0)
    w = w.reshape(-1).at[jnp.clip(tl, 0)].add(add).reshape(w.shape)
    o_ref[...] = w.astype(o_ref.dtype)


def sl_densify(B, A, idx, vals, scale=1.0, bd=DEF_BD, bp=DEF_BP):
    """Dense ``scale*(B@A) ⊕_idx vals`` via the tiled Pallas kernel.

    ``idx`` must be a static (numpy) array — it parameterizes the kernel.
    """
    d, p = B.shape[0], A.shape[1]
    bd, bp = min(bd, d), min(bp, p)
    Bp = _pad_to(B, bd, 0)
    Ap = _pad_to(A, bp, 1)
    dp_, pp_ = Bp.shape[0], Ap.shape[1]
    gd, gp = dp_ // bd, pp_ // bp
    # Decode with the TRUE p, bucket into the PADDED tile grid.
    tile_local, tile_gather, cap = bucket_support(np.asarray(idx), p, bd, bp, gd, gp)
    tl = jnp.asarray(tile_local.reshape(gd, gp, cap))
    tv = jnp.take(vals, jnp.asarray(tile_gather.reshape(gd, gp, cap)), axis=0)

    out = pl.pallas_call(
        functools.partial(_densify_kernel, scale=scale, bp=bp),
        grid=(gd, gp),
        in_specs=[
            pl.BlockSpec((bd, B.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((A.shape[0], bp), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1, cap), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bd, bp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp_, pp_), B.dtype),
        interpret=True,
    )(Bp, Ap, tl, tv)
    return out[:d, :p]


def _matmul_kernel(x_ref, B_ref, A_ref, tl_ref, tv_ref, o_ref, *, scale, nk):
    """Fused y += x_tile @ (BA ⊕ V)_tile; W tile lives only in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = scale * jnp.dot(B_ref[...], A_ref[...], preferred_element_type=jnp.float32)
    tl = tl_ref[...].reshape(-1)
    tv = tv_ref[...].reshape(-1)
    add = jnp.where(tl >= 0, tv, 0.0)
    w = w.reshape(-1).at[jnp.clip(tl, 0)].add(add).reshape(w.shape)
    o_ref[...] += jnp.dot(
        x_ref[...], w.astype(x_ref.dtype), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def sl_matmul(x, B, A, idx, vals, scale=1.0, bm=DEF_BM, bd=DEF_BD, bp=DEF_BP):
    """Fused ``y = x @ (scale*BA ⊕_idx vals)``.

    The densified W is built tile-by-tile in VMEM and contracted
    immediately — it never exists in HBM (Algorithm 1's "never store it"
    made structural). Grid is (m-tiles, p-tiles, d-tiles) with d as the
    innermost reduction.
    """
    m, d = x.shape
    p = A.shape[1]
    bm, bd, bp = min(bm, m), min(bd, d), min(bp, p)
    xp = _pad_to(_pad_to(x, bm, 0), bd, 1)
    Bp = _pad_to(B, bd, 0)
    Ap = _pad_to(A, bp, 1)
    mp_, dp_, pp_ = xp.shape[0], Bp.shape[0], Ap.shape[1]
    gm, gd, gp = mp_ // bm, dp_ // bd, pp_ // bp
    tile_local, tile_gather, cap = bucket_support(np.asarray(idx), p, bd, bp, gd, gp)
    tl = jnp.asarray(tile_local.reshape(gd, gp, cap))
    tv = jnp.take(vals, jnp.asarray(tile_gather.reshape(gd, gp, cap)), axis=0)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, scale=scale, nk=gd),
        grid=(gm, gp, gd),
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, B.shape[1]), lambda i, j, k: (k, 0)),
            pl.BlockSpec((A.shape[0], bp), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 1, cap), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda i, j, k: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp_, pp_), x.dtype),
        interpret=True,
    )(xp, Bp, Ap, tl, tv)
    return out[:m, :p]


def _dvals_kernel(x_ref, dy_ref, rows_ref, cols_ref, o_ref):
    """dvals chunk: sum_m x[:, rows] * dy[:, cols] for one nnz chunk."""
    rows = rows_ref[...].reshape(-1)
    cols = cols_ref[...].reshape(-1)
    xr = x_ref[...][:, rows]  # [m, chunk]
    yc = dy_ref[...][:, cols]  # [m, chunk]
    o_ref[...] = jnp.sum(xr * yc, axis=0).reshape(o_ref.shape)


def sl_dvals(x, dy, idx, p, chunk=4096):
    """Gathered ``(x^T dy)_idx`` without materializing the [d,p] gradient.

    Chunked over nnz so the [m, chunk] gathers bound VMEM; this is the
    eq. (2) ∇V term and the only gradient that touches the support.
    """
    idx = np.asarray(idx)
    nnz = idx.shape[0]
    chunk = min(chunk, max(1, nnz))
    pad = (-nnz) % chunk
    idx_p = np.concatenate([idx, np.zeros(pad, dtype=idx.dtype)]) if pad else idx
    rows = jnp.asarray((idx_p // p).astype(np.int32).reshape(-1, chunk))
    cols = jnp.asarray((idx_p % p).astype(np.int32).reshape(-1, chunk))
    nchunks = rows.shape[0]

    out = pl.pallas_call(
        _dvals_kernel,
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda c: (0, 0)),
            pl.BlockSpec(dy.shape, lambda c: (0, 0)),
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((nchunks, chunk), x.dtype),
        interpret=True,
    )(x, dy, rows, cols)
    return out.reshape(-1)[:nnz]


def make_sl_linear(idx: np.ndarray, p: int, scale: float, use_pallas: bool = True):
    """Build a differentiable SLTrain linear op for a FIXED support.

    Returns ``f(x, B, A, vals) -> y`` with a custom VJP implementing
    eq. (2): backward recomputes the densified W (never stored), computes
    dB/dA through [m, r] temporaries, and dvals by chunked gather.

    The support is captured statically (compile-time constant), matching
    the paper's fixed-random-support strategy, so the returned op is
    jit/lower-friendly with only (x, B, A, vals) as runtime operands.
    """
    idx = np.asarray(idx)
    from . import ref

    @jax.custom_vjp
    def f(x, B, A, vals):
        if use_pallas:
            return sl_matmul(x, B, A, idx, vals, scale)
        return ref.sl_linear(x, B, A, jnp.asarray(idx), vals, scale)

    def fwd(x, B, A, vals):
        return f(x, B, A, vals), (x, B, A, vals)

    def bwd(res, dy):
        x, B, A, vals = res
        dB = scale * (x.T @ (dy @ A.T))
        dA = scale * ((x @ B).T @ dy)
        if use_pallas:
            dvals = sl_dvals(x, dy, idx, p)
            dx = sl_matmul(dy, A.T, B.T, _transpose_support(idx, B.shape[0], p), vals, scale)
        else:
            rows, cols = idx // p, idx % p
            dvals = jnp.sum(x[:, rows] * dy[:, cols], axis=0)
            dx = dy @ ref.densify(B, A, jnp.asarray(idx), vals, scale).T
        return dx, dB, dA, dvals

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _transpose_support_cached(idx_bytes: bytes, d: int, p: int):
    idx = np.frombuffer(idx_bytes, dtype=np.int32)
    rows, cols = idx // p, idx % p
    return (cols * d + rows).astype(np.int32)


def _transpose_support(idx: np.ndarray, d: int, p: int) -> np.ndarray:
    """Flat support of W^T given flat support of W ([d,p] row-major).

    NOTE: the transposed support is *unsorted* relative to vals' order —
    by design, so ``vals[k]`` still pairs with entry k. Used for the
    dx = dy @ W^T recompute-path where W^T = (BA ⊕ V)^T = A^T B^T ⊕_T V.
    """
    idx = np.ascontiguousarray(np.asarray(idx, dtype=np.int32))
    return _transpose_support_cached(idx.tobytes(), d, p)

"""L1 kernels: Pallas SLTrain linear (sl_linear) + pure-jnp oracle (ref)."""
from . import ref  # noqa: F401
from . import sl_linear  # noqa: F401

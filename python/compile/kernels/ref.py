"""Pure-jnp correctness oracle for the SLTrain linear layer.

This is the executable specification of the paper's Algorithm 1 and its
gradient equations (eq. 2). Every Pallas kernel in `sl_linear.py` is
checked against these functions by pytest (`python/tests/`), and the L2
model can be built against either implementation (``use_pallas`` switch)
so a kernel regression is always isolatable.

Conventions (used across the whole repo):
  x : [m, d_in]            activations, row-major batch
  B : [d_in, r]            left low-rank factor   (zero-init in SLTrain)
  A : [r, d_out]           right low-rank factor  (Kaiming-init)
  idx : [nnz] int32        FIXED support, flat row-major into d_in*d_out
  vals: [nnz] float        learned sparse values
  scale : float            the paper's alpha/r balancing factor on B@A

  W = scale * (B @ A)  ⊕_idx  vals          (scatter-add densify)
  y = x @ W
"""

from __future__ import annotations

import jax.numpy as jnp


def densify(B, A, idx, vals, scale=1.0):
    """Return the dense ``scale*(B@A) ⊕_idx vals`` matrix.

    This is the transient matrix of Algorithm 1 line 4; the paper (and our
    kernels) never *store* it for backprop — the oracle materializes it
    for comparison purposes only.
    """
    d, p = B.shape[0], A.shape[1]
    W = scale * (B @ A)
    return W.reshape(-1).at[idx].add(vals, mode="drop").reshape(d, p)


def sl_linear(x, B, A, idx, vals, scale=1.0):
    """Forward of Algorithm 1: ``(scale*BA ⊕_idx vals) x``."""
    return x @ densify(B, A, idx, vals, scale)


def sl_linear_grads(x, B, A, idx, vals, dy, scale=1.0):
    """Closed-form gradients of eq. (2), adapted to y = x @ W.

    Returns (dx, dB, dA, dvals). Matches what jax.grad of `sl_linear`
    produces, but — like the paper — never materializes the dense dW:

      dB    = scale * x^T (dy A^T)      -- [d,r]   via [m,r] temp
      dA    = scale * (x B)^T dy        -- [r,p]   via [m,r] temp
      dvals = (x^T dy)_idx              -- gathered, chunked in kernels
      dx    = dy W^T                    -- recomputes W (not stored)
    """
    p = A.shape[1]
    rows, cols = idx // p, idx % p
    dB = scale * (x.T @ (dy @ A.T))
    dA = scale * ((x @ B).T @ dy)
    dvals = jnp.sum(x[:, rows] * dy[:, cols], axis=0)
    dx = dy @ densify(B, A, idx, vals, scale).T
    return dx, dB, dA, dvals


def lowrank_linear(x, B, A, scale=1.0):
    """Baseline Low-Rank [24] layer: y = scale * x B A (no densify)."""
    return scale * ((x @ B) @ A)


def random_support(seed, d, p, delta):
    """Uniform random support of the paper's Section 3.2: nnz = delta*d*p
    distinct flat indices, sorted ascending. Takes an int seed and runs on
    the numpy path — supports are chosen once at init and are *static*
    constants baked into the lowered HLO (the paper's fixed-support
    strategy made structural)."""
    import numpy as np

    nnz = max(1, int(round(delta * d * p)))
    rng = np.random.default_rng(seed)
    idx = rng.choice(d * p, size=nnz, replace=False)
    return np.sort(idx).astype(np.int32)

"""AOT lowering: JAX -> HLO *text* artifacts + manifest.json.

Run once via `make artifacts`. For each (config, method) pair this emits

  artifacts/<cfg>_<method>[_8bit]/
    init.hlo.txt        (seed u32) -> (*params, *opt_state)
    train_step.hlo.txt  (step i32, tokens i32[b,s], *consts, *params, *opt)
                          -> (loss f32, *params, *opt)
    eval_step.hlo.txt   (tokens, *consts, *params) -> loss
    forward.hlo.txt     (tokens_fwd, *consts, *params) -> logits
    merge.hlo.txt       (relora only) (seed i32, *params) -> (*params)
    manifest.json       the contract the rust runtime programs against
    <name>.support.bin  u32-LE sidecars with the fixed sparse supports

HLO TEXT is the interchange format, not `.serialize()`: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids);
the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model as model_lib, optim


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(d):
    return {
        jnp.float32.dtype: "f32",
        jnp.int32.dtype: "i32",
        jnp.int8.dtype: "i8",
        jnp.uint32.dtype: "u32",
    }[jnp.dtype(d)]


def build_bundle(cfg, method, batch, opt8bit=False, use_pallas=False,
                 support_seed=42, lr=3e-3, warmup=100, total_steps=2000,
                 wd=0.0, galore_refresh=200, freeze_lowrank=False,
                 ft_freeze_base=False):
    """Construct all entrypoint callables + specs for one artifact set.

    freeze_lowrank: train ONLY the sparse values (paper Table 1's
    "L0 + sparse training" rows — everything else held at init/injected).
    ft_freeze_base: freeze embeddings + norms (fine-tuning setups,
    Appendix G) so only adaptors (+head) update.
    """
    m = model_lib.build(cfg, method, support_seed, use_pallas)
    if freeze_lowrank:
        m.trainable = [n for n in m.trainable if n.endswith(".vals")]
    if ft_freeze_base:
        m.trainable = [
            n for n in m.trainable
            if n != "embed.w" and not n.endswith(".g")
        ]
    opt_kind = "galore" if method == "galore" else ("adam8bit" if opt8bit else "adam")
    if method == "galore" and opt8bit:
        opt_kind = "galore"  # paper's 8-bit GaLore quantizes moments too;
        # we account it in mem/ but keep f32 states in-graph for clarity
    pnames = m.param_names
    cnames = m.const_names
    pshapes = {n: m.shape_of(n) for n in pnames}
    tshapes = {n: pshapes[n] for n in m.trainable}

    ostate0 = optim.opt_init(opt_kind, tshapes, cfg.rank, seed=support_seed)
    onames = sorted(ostate0.keys())
    oshapes = {n: tuple(ostate0[n].shape) for n in onames}
    odtypes = {n: ostate0[n].dtype for n in onames}

    def init_fn(seed):
        params = m.init_fn(jax.random.PRNGKey(seed))
        ost = optim.opt_init(opt_kind, tshapes, cfg.rank, seed=support_seed)
        return tuple(params[n] for n in pnames) + tuple(ost[n] for n in onames)

    def _unpack(consts_list, params_list, opt_list=None):
        consts = dict(zip(cnames, consts_list))
        params = dict(zip(pnames, params_list))
        ost = dict(zip(onames, opt_list)) if opt_list is not None else None
        return consts, params, ost

    def train_step(step, tokens, *rest):
        consts_list = rest[: len(cnames)]
        params_list = rest[len(cnames) : len(cnames) + len(pnames)]
        opt_list = rest[len(cnames) + len(pnames) :]
        consts, params, ost = _unpack(consts_list, params_list, opt_list)

        def loss_of(tp):
            full = dict(params)
            full.update(tp)
            return m.loss_fn(full, consts, tokens)

        tparams = {n: params[n] for n in m.trainable}
        loss, grads = jax.value_and_grad(loss_of)(tparams)
        lr_t = optim.lr_schedule(step, lr, warmup, total_steps)
        kw = dict(wd=wd)
        if opt_kind == "galore":
            kw["refresh_every"] = galore_refresh
        new_t, new_o = optim.opt_update(
            opt_kind, tparams, grads, ost, step, lr_t, cfg.rank, **kw
        )
        out_params = dict(params)
        out_params.update(new_t)
        return (loss,) + tuple(out_params[n] for n in pnames) + tuple(
            new_o[n] for n in onames
        )

    def eval_step(tokens, *rest):
        consts, params, _ = _unpack(rest[: len(cnames)], rest[len(cnames) :])
        return (m.loss_fn(params, consts, tokens),)

    def forward(tokens, *rest):
        consts, params, _ = _unpack(rest[: len(cnames)], rest[len(cnames) :])
        return (m.apply_fn(params, consts, tokens),)

    merge_fn = None
    if method == "relora":
        merge_inner = model_lib.make_relora_merge(cfg)

        def merge_fn(seed, *params_list):
            params = dict(zip(pnames, params_list))
            out = merge_inner(params, seed)
            return tuple(out[n] for n in pnames)

    return dict(
        model=m, opt_kind=opt_kind, pnames=pnames, cnames=cnames,
        onames=onames, pshapes=pshapes, oshapes=oshapes, odtypes=odtypes,
        init_fn=init_fn, train_step=train_step, eval_step=eval_step,
        forward=forward, merge_fn=merge_fn, batch=batch,
        hyper=dict(lr=lr, warmup=warmup, total_steps=total_steps, wd=wd,
                   galore_refresh=galore_refresh),
    )


def emit_bundle(cfg, method, out_dir, batch, fwd_batch=None, **kw):
    b = build_bundle(cfg, method, batch, **kw)
    m = b["model"]
    os.makedirs(out_dir, exist_ok=True)
    fwd_batch = fwd_batch or batch
    s = cfg.seq_len

    csds = [_sds(m.shape_of(n), jnp.int32) for n in b["cnames"]]
    psds = [_sds(b["pshapes"][n]) for n in b["pnames"]]
    osds = [_sds(b["oshapes"][n], b["odtypes"][n]) for n in b["onames"]]
    tok = _sds((batch, s), jnp.int32)
    tok_fwd = _sds((fwd_batch, s), jnp.int32)

    entry = {}

    def emit(name, fn, args, donate=()):
        jitted = jax.jit(fn, donate_argnums=donate)
        text = to_hlo_text(jitted.lower(*args))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        return fname

    # donate params+opt in train_step so PJRT can alias in/out buffers
    n_pre = 2 + len(csds)
    donate = tuple(range(n_pre, n_pre + len(psds) + len(osds)))
    entry["train_step"] = {
        "file": emit(
            "train_step", b["train_step"],
            [_sds((), jnp.int32), tok] + csds + psds + osds, donate,
        ),
        "inputs": ["__step", "__tokens"] + b["cnames"] + b["pnames"]
        + b["onames"],
        "outputs": ["__loss"] + b["pnames"] + b["onames"],
        "batch": batch,
    }
    entry["init"] = {
        "file": emit("init", b["init_fn"], [_sds((), jnp.uint32)]),
        "inputs": ["__seed"],
        "outputs": b["pnames"] + b["onames"],
    }
    entry["eval_step"] = {
        "file": emit("eval_step", b["eval_step"], [tok] + csds + psds),
        "inputs": ["__tokens"] + b["cnames"] + b["pnames"],
        "outputs": ["__loss"],
        "batch": batch,
    }
    entry["forward"] = {
        "file": emit("forward", b["forward"], [tok_fwd] + csds + psds),
        "inputs": ["__tokens"] + b["cnames"] + b["pnames"],
        "outputs": ["__logits"],
        "batch": fwd_batch,
    }
    if b["merge_fn"] is not None:
        entry["merge"] = {
            "file": emit("merge", b["merge_fn"], [_sds((), jnp.int32)] + psds),
            "inputs": ["__seed"] + b["pnames"],
            "outputs": b["pnames"],
        }

    supports = {}
    for n, idx in m.supports.items():
        fname = n.replace("/", "_") + ".support.bin"
        np.asarray(idx, dtype=np.uint32).tofile(os.path.join(out_dir, fname))
        supports[n] = {"file": fname, "nnz": int(len(idx))}

    manifest = {
        "config": cfg.to_dict(),
        "method": method,
        "optimizer": {"type": b["opt_kind"], **b["hyper"]},
        "batch": batch,
        "fwd_batch": fwd_batch,
        "n_params": m.n_params(),
        "params": [
            {
                "name": n,
                "shape": list(m.shape_of(n)),
                "dtype": "f32",
                "trainable": n in m.trainable,
            }
            for n in b["pnames"]
        ],
        "consts": [
            {"name": n, "shape": list(m.shape_of(n)), "dtype": "i32"}
            for n in b["cnames"]
        ],
        "opt_state": [
            {
                "name": n,
                "shape": list(b["oshapes"][n]),
                "dtype": _dtype_name(b["odtypes"][n]),
            }
            for n in b["onames"]
        ],
        "supports": supports,
        "entrypoints": entry,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


# ------------------------------------------------- Fig 12 layer-stack bench


def emit_mlp_stack(out_dir, depth, width, rank, delta, batch, kind,
                   support_seed=7):
    """N-layer feed-forward stack artifacts for the Appendix E (Fig 12)
    layer-level memory/runtime comparison: kind in {ffn, lowrank, sltrain}.
    Emits a fwd loss + SGD-step program over the stack."""
    from .kernels import ref

    os.makedirs(out_dir, exist_ok=True)
    shapes = {}
    supports = {}
    for i in range(depth):
        if kind == "ffn":
            shapes[f"l{i}.w"] = (width, width)
        else:
            shapes[f"l{i}.B"] = (width, rank)
            shapes[f"l{i}.A"] = (rank, width)
            if kind == "sltrain":
                nnz = max(1, int(round(delta * width * width)))
                shapes[f"l{i}.vals"] = (nnz,)
                supports[f"l{i}.idx"] = ref.random_support(
                    support_seed + i, width, width, delta
                )
    pnames = sorted(shapes)
    cnames = sorted(supports)

    def apply(params, consts, x):
        for i in range(depth):
            if kind == "ffn":
                x = x @ params[f"l{i}.w"]
            elif kind == "lowrank":
                x = ref.lowrank_linear(x, params[f"l{i}.B"], params[f"l{i}.A"])
            else:
                x = ref.sl_linear(
                    x, params[f"l{i}.B"], params[f"l{i}.A"],
                    consts[f"l{i}.idx"], params[f"l{i}.vals"],
                )
            x = jax.nn.relu(x)
        return x

    def step(x, *rest):
        consts = dict(zip(cnames, rest[: len(cnames)]))
        params = dict(zip(pnames, rest[len(cnames) :]))

        def loss_of(p):
            return jnp.mean(jnp.square(apply(p, consts, x)))

        loss, g = jax.value_and_grad(loss_of)(params)
        out = {n: params[n] - 1e-3 * g[n] for n in pnames}
        return (loss,) + tuple(out[n] for n in pnames)

    x = _sds((batch, width))
    csds = [_sds(supports[n].shape, jnp.int32) for n in cnames]
    psds = [_sds(shapes[n]) for n in pnames]
    jitted = jax.jit(step)
    text = to_hlo_text(jitted.lower(x, *csds, *psds))
    fname = f"stack_{kind}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    sup = {}
    for n, idx in supports.items():
        sf = n.replace("/", "_") + ".support.bin"
        np.asarray(idx, dtype=np.uint32).tofile(os.path.join(out_dir, sf))
        sup[n] = {"file": sf, "nnz": int(len(idx))}
    manifest = {
        "kind": kind, "depth": depth, "width": width, "rank": rank,
        "delta": delta, "batch": batch,
        "params": [
            {"name": n, "shape": list(shapes[n]), "dtype": "f32",
             "trainable": True}
            for n in pnames
        ],
        "consts": [
            {"name": n, "shape": [sup[n]["nnz"]], "dtype": "i32"}
            for n in cnames
        ],
        "supports": sup,
        "entrypoints": {
            "step": {
                "file": fname,
                "inputs": ["__x"] + cnames + pnames,
                "outputs": ["__loss"] + pnames,
                "batch": batch,
            }
        },
    }
    with open(os.path.join(out_dir, f"stack_{kind}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


DEFAULT_SETS = [
    # (config, method, batch, opt8bit) — the minimum set `make artifacts`
    # builds; benches request more via explicit flags.
    ("tiny", "full", 8, False),
    ("tiny", "lowrank", 8, False),
    ("tiny", "sltrain", 8, False),
    ("tiny", "relora", 8, False),
    ("tiny", "galore", 8, False),
    ("tiny", "sltrain", 8, True),
]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--config", default=None, help="preset name (default: tiny set)")
    ap.add_argument("--method", default=None, choices=configs.METHODS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fwd-batch", type=int, default=None)
    ap.add_argument("--opt8bit", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas kernel path inside the model")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--total-steps", type=int, default=2000)
    ap.add_argument("--support-seed", type=int, default=42)
    ap.add_argument("--suffix", default="", help="artifact dir name suffix")
    ap.add_argument("--delta", type=float, default=None, help="override sparsity")
    ap.add_argument("--rank", type=int, default=None, help="override rank")
    ap.add_argument("--freeze-lowrank", action="store_true",
                    help="train only sparse values (Table 1 ablation)")
    ap.add_argument("--ft-freeze-base", action="store_true",
                    help="freeze embed+norms (fine-tuning, Appendix G)")
    ap.add_argument("--mlp-stack", default=None,
                    help="emit Fig-12 stack artifacts: depth,width,rank,delta,batch")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.mlp_stack:
        depth, width, rank = [int(v) for v in args.mlp_stack.split(",")[:3]]
        delta = float(args.mlp_stack.split(",")[3])
        batch = int(args.mlp_stack.split(",")[4])
        d = os.path.join(args.out, "mlp_stack")
        for kind in ("ffn", "lowrank", "sltrain"):
            emit_mlp_stack(d, depth, width, rank, delta, batch, kind)
            print(f"emitted {d}/stack_{kind}")
        return

    sets = (
        [(args.config, args.method, args.batch, args.opt8bit)]
        if args.config and args.method
        else DEFAULT_SETS
    )
    for cfg_name, method, batch, opt8 in sets:
        cfg = configs.get(cfg_name)
        if args.delta is not None or args.rank is not None:
            import dataclasses

            cfg = dataclasses.replace(
                cfg,
                delta=args.delta if args.delta is not None else cfg.delta,
                rank=args.rank if args.rank is not None else cfg.rank,
            )
        tag = f"{cfg_name}_{method}" + ("_8bit" if opt8 else "") + args.suffix
        out_dir = os.path.join(args.out, tag)
        man = emit_bundle(
            cfg, method, out_dir, batch, fwd_batch=args.fwd_batch,
            opt8bit=opt8, use_pallas=args.pallas, lr=args.lr,
            warmup=args.warmup, total_steps=args.total_steps,
            support_seed=args.support_seed,
            freeze_lowrank=args.freeze_lowrank,
            ft_freeze_base=args.ft_freeze_base,
        )
        print(
            f"emitted {tag}: {man['n_params']/1e6:.2f}M params, "
            f"{len(man['params'])} tensors, opt={man['optimizer']['type']}"
        )


if __name__ == "__main__":
    main()

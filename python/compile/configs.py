"""Model presets for the scaled LLaMA family (see DESIGN.md §5).

The paper trains LLaMA 60M/130M/350M/1B/7B on A100s; our testbed is the
CPU PJRT client, so each preset keeps the paper's architectural shape
(pre-norm, RMSNorm, SwiGLU, rotary) and its r/d ratio, at reduced width.
`spec7b` carries the paper's exact 7B dimensions and exists only for the
analytic memory estimator (Table 4 / Fig 3) — it is never trained here.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict


def _ff(d: int) -> int:
    """LLaMA SwiGLU hidden size: 2/3 * 4d rounded up to a multiple of 64."""
    return ((8 * d // 3) + 63) // 64 * 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    rank: int  # r for low-rank factors (per Table 2 ratios)
    delta: float = 0.03  # sparsity level (paper default §5.1)
    alpha: float = 32.0  # low-rank balancing factor (scale = alpha/rank)
    d_ff: int = 0  # 0 -> derived
    rope_theta: float = 10000.0
    # which linear layers are reparameterized (paper: all attn+mlp linears)
    adapt_attn: bool = True
    adapt_mlp: bool = True

    def __post_init__(self):
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", _ff(self.d_model))
        assert self.d_model % self.n_heads == 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def scale(self) -> float:
        return self.alpha / self.rank

    def to_dict(self):
        return asdict(self)


# name -> (vocab, d, L, H, seq, r, alpha); delta defaults to 0.03.
PRESETS = {
    # CI/test scale
    "tiny": ModelConfig("tiny", 256, 64, 2, 2, 64, 16, alpha=32.0),
    "tiny2": ModelConfig("tiny2", 512, 96, 3, 4, 64, 24, alpha=32.0),
    # scaled counterparts of the paper's table rows (keep r/d = 1/4 at the
    # 60M point, matching 128/512; alpha follows §5.1's tuned values)
    "s60m": ModelConfig("s60m", 4096, 192, 4, 4, 128, 48, alpha=32.0),
    "s130m": ModelConfig("s130m", 4096, 256, 6, 8, 128, 64, alpha=16.0),
    "s350m": ModelConfig("s350m", 8192, 384, 8, 8, 192, 96, alpha=16.0),
    "s1b": ModelConfig("s1b", 8192, 512, 10, 8, 256, 128, alpha=8.0),
    # end-to-end example target (~100M params)
    "e2e100m": ModelConfig("e2e100m", 24576, 640, 14, 10, 256, 160, alpha=16.0),
    # analytic-only: the paper's exact LLaMA 7B dims (Table 4), delta=0.05
    "spec7b": ModelConfig(
        "spec7b", 32000, 4096, 32, 32, 2048, 1024, delta=0.05, alpha=8.0, d_ff=11008
    ),
}

METHODS = ("full", "lowrank", "sltrain", "relora", "galore", "sltrain_ft")


def get(name: str) -> ModelConfig:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]

"""Optimizers lowered into the train-step artifacts.

Three families, matching the paper's experimental matrix:

  adam      — the baseline optimizer for Full-Rank / Low-Rank / ReLoRA /
              SLTrain (the paper stresses SLTrain is optimizer-agnostic).
  adam8bit  — block-wise int8-quantized moments (Dettmers et al. [9]),
              used for the 7B-scale rows (Table 4) and Fig 3.
  galore    — Adam with the gradient of each adapted matrix projected to a
              rank-k subspace (Zhao et al. [59]). The paper computes the
              projector from a truncated SVD of G every T steps; LAPACK
              custom-calls don't exist in the rust PJRT runtime, so we use
              warm-started subspace iteration + Newton–Schulz
              orthonormalization — pure matmuls, same top subspace
              (substitution documented in DESIGN.md §3).

All states are flat dicts keyed off the trainable parameter name, so the
rust runtime can treat optimizer buffers exactly like parameters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 256  # 8-bit quantization block size (as in bitsandbytes)


def lr_schedule(step, base_lr, warmup, total):
    """Linear warmup then cosine decay to 10% — the GaLore-repo schedule
    the paper trains with."""
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = base_lr * (0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


# ------------------------------------------------------------------- Adam


def adam_init(shapes):
    """shapes: {name: shape} -> state {name.m, name.v}."""
    st = {}
    for n, s in shapes.items():
        st[f"{n}.m"] = jnp.zeros(s, jnp.float32)
        st[f"{n}.v"] = jnp.zeros(s, jnp.float32)
    return st


def adam_update(params, grads, state, step, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    new_p, new_s = dict(params), dict(state)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    for n, g in grads.items():
        m = b1 * state[f"{n}.m"] + (1 - b1) * g
        v = b2 * state[f"{n}.v"] + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if wd:
            upd = upd + wd * params[n]
        new_p[n] = params[n] - lr * upd
        new_s[f"{n}.m"] = m
        new_s[f"{n}.v"] = v
    return new_p, new_s


# --------------------------------------------------------------- 8-bit Adam


def _qshape(shape):
    n = int(np.prod(shape))
    nb = -(-n // QBLOCK)
    return n, nb


def quantize_blockwise(x):
    """x flat f32 [n] (padded to QBLOCK) -> (int8 codes, f32 per-block absmax)."""
    xb = x.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xb / safe * 127.0), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_blockwise(q, scale):
    xb = q.reshape(-1, QBLOCK).astype(jnp.float32) / 127.0
    return (xb * scale[:, None]).reshape(-1)


def adam8bit_init(shapes):
    st = {}
    for n, s in shapes.items():
        _, nb = _qshape(s)
        st[f"{n}.mq"] = jnp.zeros((nb * QBLOCK,), jnp.int8)
        st[f"{n}.ms"] = jnp.zeros((nb,), jnp.float32)
        st[f"{n}.vq"] = jnp.zeros((nb * QBLOCK,), jnp.int8)
        st[f"{n}.vs"] = jnp.zeros((nb,), jnp.float32)
    return st


def adam8bit_update(params, grads, state, step, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """Dequantize -> Adam moment update -> requantize, block-wise.

    Second moment is quantized in sqrt-space to preserve dynamic range
    (the [9] trick, simplified to linear-in-sqrt rather than dynamic-tree).
    """
    new_p, new_s = dict(params), dict(state)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    for n, g in grads.items():
        shape = g.shape
        npad = state[f"{n}.mq"].shape[0]
        gf = jnp.pad(g.reshape(-1), (0, npad - g.size))
        m = dequantize_blockwise(state[f"{n}.mq"], state[f"{n}.ms"])
        v = jnp.square(dequantize_blockwise(state[f"{n}.vq"], state[f"{n}.vs"]))
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        upd = ((m / bc1) / (jnp.sqrt(v / bc2) + eps))[: g.size].reshape(shape)
        if wd:
            upd = upd + wd * params[n]
        new_p[n] = params[n] - lr * upd
        mq, ms = quantize_blockwise(m)
        vq, vs = quantize_blockwise(jnp.sqrt(v))
        new_s[f"{n}.mq"], new_s[f"{n}.ms"] = mq, ms
        new_s[f"{n}.vq"], new_s[f"{n}.vs"] = vq, vs
    return new_p, new_s


# ------------------------------------------------------------------ GaLore


def newton_schulz_invsqrt(S, iters=12, eps=1e-6):
    """S^{-1/2} for SPD S [k,k] via coupled Newton–Schulz (pure matmuls)."""
    k = S.shape[0]
    I = jnp.eye(k, dtype=S.dtype)
    S = S + eps * I
    norm = jnp.sqrt(jnp.sum(jnp.square(S)))
    Y = S / norm
    Z = I
    for _ in range(iters):
        T = 0.5 * (3.0 * I - Z @ Y)
        Y = Y @ T
        Z = T @ Z
    return Z / jnp.sqrt(norm)


def orthonormalize(Y):
    """Columns of Y -> orthonormal basis of span(Y): Y (YᵀY)^{-1/2}."""
    return Y @ newton_schulz_invsqrt(Y.T @ Y)


def subspace_iter(G, P_prev, iters=2):
    """Warm-started subspace iteration for the top-k left singular vectors
    of G [d,p] (k = P_prev.shape[1]). Replaces the paper's torch.svd."""
    P = P_prev
    for _ in range(iters):
        P = orthonormalize(G @ (G.T @ P))
    return P


def galore_targets(param_shapes, rank):
    """Which params get projected: 2D matrices from the adapted linears
    (name 'layers.*.w'), exactly GaLore's target_modules behaviour."""
    out = {}
    for n, s in param_shapes.items():
        if n.startswith("layers.") and n.endswith(".w") and len(s) == 2:
            d, p = s
            k = min(rank, d, p)
            side = "left" if d <= p else "right"
            out[n] = (side, k)
    return out


def galore_init(param_shapes, rank, seed=0):
    """Adam moments in projected space + the projector P per target.
    Non-target params carry plain Adam moments."""
    st = {}
    targets = galore_targets(param_shapes, rank)
    key = jax.random.PRNGKey(seed)
    for n, s in param_shapes.items():
        if n in targets:
            side, k = targets[n]
            d, p = s
            key, sub = jax.random.split(key)
            if side == "left":
                P0 = orthonormalize(jax.random.normal(sub, (d, k), jnp.float32))
                ms = (k, p)
            else:
                P0 = orthonormalize(jax.random.normal(sub, (p, k), jnp.float32))
                ms = (d, k)
            st[f"{n}.P"] = P0
            st[f"{n}.m"] = jnp.zeros(ms, jnp.float32)
            st[f"{n}.v"] = jnp.zeros(ms, jnp.float32)
        else:
            st[f"{n}.m"] = jnp.zeros(s, jnp.float32)
            st[f"{n}.v"] = jnp.zeros(s, jnp.float32)
    return st


def galore_update(
    params, grads, state, step, lr, rank,
    b1=0.9, b2=0.999, eps=1e-8, wd=0.0, refresh_every=200, gl_scale=0.25,
):
    """GaLore §2: moments live in the projected space; the weight update is
    the projected-back Adam direction. P refreshed every `refresh_every`
    steps (lax.cond so the artifact stays a single program)."""
    new_p, new_s = dict(params), dict(state)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    targets = galore_targets({n: g.shape for n, g in grads.items()}, rank)
    refresh = jnp.logical_or(step == 0, (step % refresh_every) == 0)
    for n, g in grads.items():
        if n in targets:
            side, k = targets[n]
            P_old = state[f"{n}.P"]
            GG = g if side == "left" else g.T
            P = jax.lax.cond(
                refresh, lambda: subspace_iter(GG, P_old), lambda: P_old
            )
            gp = P.T @ g if side == "left" else g @ P
            m = b1 * state[f"{n}.m"] + (1 - b1) * gp
            v = b2 * state[f"{n}.v"] + (1 - b2) * jnp.square(gp)
            upd_p = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            upd = P @ upd_p if side == "left" else upd_p @ P.T
            upd = gl_scale * upd
            if wd:
                upd = upd + wd * params[n]
            new_p[n] = params[n] - lr * upd
            new_s[f"{n}.P"] = P
            new_s[f"{n}.m"] = m
            new_s[f"{n}.v"] = v
        else:
            m = b1 * state[f"{n}.m"] + (1 - b1) * g
            v = b2 * state[f"{n}.v"] + (1 - b2) * jnp.square(g)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if wd:
                upd = upd + wd * params[n]
            new_p[n] = params[n] - lr * upd
            new_s[f"{n}.m"] = m
            new_s[f"{n}.v"] = v
    return new_p, new_s


def opt_init(kind, shapes, rank=0, seed=0):
    if kind == "adam":
        return adam_init(shapes)
    if kind == "adam8bit":
        return adam8bit_init(shapes)
    if kind == "galore":
        return galore_init(shapes, rank, seed)
    raise ValueError(kind)


def opt_update(kind, params, grads, state, step, lr, rank=0, **kw):
    if kind == "adam":
        return adam_update(params, grads, state, step, lr, **kw)
    if kind == "adam8bit":
        return adam8bit_update(params, grads, state, step, lr, **kw)
    if kind == "galore":
        return galore_update(params, grads, state, step, lr, rank, **kw)
    raise ValueError(kind)

"""L2 model tests: shapes, parameterizations, loss behaviour, ReLoRA merge."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model as model_lib

CFG = configs.get("tiny")


def _setup(method, seed=0):
    m = model_lib.build(CFG, method, support_seed=7)
    params = m.init_fn(jax.random.PRNGKey(seed))
    consts = {n: jnp.asarray(m.supports[n]) for n in m.const_names}
    return m, params, consts


def _tokens(seed=0, b=4):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, CFG.seq_len)).astype(np.int32))


class TestSpecs:
    @pytest.mark.parametrize("method", configs.METHODS)
    def test_init_matches_specs(self, method):
        m, params, _ = _setup(method)
        assert set(params) == set(m.param_names)
        for n in m.param_names:
            assert tuple(params[n].shape) == tuple(m.shape_of(n)), n

    def test_param_counts_ordering(self):
        # paper Table 2 ordering: lowrank < sltrain < full < relora
        counts = {}
        for method in ("full", "lowrank", "sltrain", "relora"):
            m, _, _ = _setup(method)
            counts[method] = m.n_params()
        assert counts["lowrank"] < counts["sltrain"] < counts["full"] < counts["relora"]

    def test_sltrain_overhead_is_delta(self):
        # sltrain adds exactly nnz = delta*d*p values per adapted linear
        mlr, _, _ = _setup("lowrank")
        msl, _, _ = _setup("sltrain")
        extra = msl.n_params() - mlr.n_params()
        expected = sum(v.shape[0] for v in msl.supports.values())
        assert extra == expected

    def test_supports_are_valid(self):
        m, _, _ = _setup("sltrain")
        for n in m.const_names:
            idx = m.supports[n]
            d, p = None, None
            # find matching linear dims from the vals spec
            base = n[: -len(".idx")]
            dB = m.shape_of(f"{base}.B")
            dA = m.shape_of(f"{base}.A")
            d, p = dB[0], dA[1]
            assert idx.min() >= 0 and idx.max() < d * p
            assert len(np.unique(idx)) == len(idx)

    def test_relora_w0_not_trainable(self):
        m, _, _ = _setup("relora")
        w0s = [n for n in m.param_names if n.endswith(".w0")]
        assert w0s
        assert not set(w0s) & set(m.trainable)


class TestForward:
    @pytest.mark.parametrize("method", configs.METHODS)
    def test_logits_shape_and_finite(self, method):
        m, params, consts = _setup(method)
        toks = _tokens()
        logits = m.apply_fn(params, consts, toks)
        assert logits.shape == (4, CFG.seq_len, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())

    @pytest.mark.parametrize("method", configs.METHODS)
    def test_initial_loss_near_uniform(self, method):
        m, params, consts = _setup(method)
        loss = float(m.loss_fn(params, consts, _tokens()))
        # CE against uniform = log(vocab); init should be in that ballpark
        assert abs(loss - np.log(CFG.vocab)) < 1.5

    def test_sltrain_starts_with_zero_lowrank(self):
        # B=0 at init: forward must equal a pure-sparse parameterization
        m, params, consts = _setup("sltrain")
        for n in m.param_names:
            if n.endswith(".B"):
                assert float(jnp.abs(params[n]).max()) == 0.0

    def test_causality(self):
        # changing a future token must not change earlier logits
        m, params, consts = _setup("full")
        toks = _tokens()
        logits1 = m.apply_fn(params, consts, toks)
        toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
        logits2 = m.apply_fn(params, consts, toks2)
        np.testing.assert_allclose(
            np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
        )


class TestGradAndMerge:
    def test_grads_flow_to_all_trainables(self):
        m, params, consts = _setup("sltrain")
        toks = _tokens()

        def loss_of(tp):
            full = dict(params)
            full.update(tp)
            return m.loss_fn(full, consts, toks)

        tparams = {n: params[n] for n in m.trainable}
        grads = jax.grad(loss_of)(tparams)
        # A-grads are nonzero even though B=0 (dA = B^T dW = 0 at init!);
        # actually dA==0 when B==0 — but vals and embed grads must flow.
        nz = {n for n, g in grads.items() if float(jnp.abs(g).max()) > 0}
        assert any(n.endswith(".vals") for n in nz)
        assert any(n.endswith(".B") for n in nz)  # dB = dW A^T != 0
        assert "embed.w" in nz

    def test_relora_merge_preserves_function(self):
        m, params, consts = _setup("relora")
        # make B nonzero so the merge actually moves mass
        key = jax.random.PRNGKey(3)
        for n in list(params):
            if n.endswith(".B"):
                key, k = jax.random.split(key)
                params[n] = jax.random.normal(k, params[n].shape) * 0.05
        toks = _tokens()
        before = m.apply_fn(params, consts, toks)
        merge = model_lib.make_relora_merge(CFG)
        merged = merge(params, jnp.int32(1))
        # after merge, B==0 so BA term vanishes; W0 absorbed it
        after = m.apply_fn(merged, consts, toks)
        np.testing.assert_allclose(
            np.asarray(before), np.asarray(after), atol=2e-4, rtol=2e-4
        )
        for n in merged:
            if n.endswith(".B"):
                assert float(jnp.abs(merged[n]).max()) == 0.0

    def test_sl_from_dense_rank_and_residual(self):
        rng = np.random.default_rng(0)
        W = rng.normal(size=(24, 32)).astype(np.float32)
        idx = np.sort(rng.choice(24 * 32, 50, replace=False)).astype(np.int32)
        B, A, vals = model_lib.sl_from_dense(W, idx, rank=4)
        assert B.shape == (24, 4) and A.shape == (4, 32) and vals.shape == (50,)
        resid = W - B @ A
        np.testing.assert_allclose(vals, resid.reshape(-1)[idx], atol=1e-5)
        B2, A2, vals2 = model_lib.sl_from_dense(W, idx, rank=4, mode="zero")
        assert np.abs(vals2).max() == 0.0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

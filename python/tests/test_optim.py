"""Optimizer tests: Adam math, 8-bit quantization fidelity, GaLore
projection (subspace-iteration vs true SVD subspace), LR schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import optim


def _quad_problem(n=16, seed=0):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    params = {"w": jnp.zeros((n,), jnp.float32)}

    def grads_of(p):
        return {"w": p["w"] - target}

    return params, grads_of, target


class TestAdam:
    def test_converges_on_quadratic(self):
        params, grads_of, target = _quad_problem()
        st_ = optim.adam_init({"w": (16,)})
        for step in range(300):
            params, st_ = optim.adam_update(
                params, grads_of(params), st_, jnp.int32(step), 0.05
            )
        assert float(jnp.abs(params["w"] - target).max()) < 1e-2

    def test_bias_correction_first_step(self):
        # after one step with grad g, Adam moves ~lr*sign(g)
        params = {"w": jnp.zeros((4,), jnp.float32)}
        g = {"w": jnp.asarray([1.0, -2.0, 3.0, -4.0], jnp.float32)}
        st_ = optim.adam_init({"w": (4,)})
        p2, _ = optim.adam_update(params, g, st_, jnp.int32(0), 0.1)
        np.testing.assert_allclose(
            np.asarray(p2["w"]), -0.1 * np.sign(np.asarray(g["w"])), atol=1e-4
        )

    def test_weight_decay(self):
        params = {"w": jnp.ones((4,), jnp.float32)}
        g = {"w": jnp.zeros((4,), jnp.float32)}
        st_ = optim.adam_init({"w": (4,)})
        p2, _ = optim.adam_update(params, g, st_, jnp.int32(0), 0.1, wd=0.5)
        assert float(p2["w"][0]) < 1.0


class TestAdam8bit:
    def test_quant_roundtrip_error(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(optim.QBLOCK * 4,)).astype(np.float32))
        q, s = optim.quantize_blockwise(x)
        xr = optim.dequantize_blockwise(q, s)
        err = float(jnp.abs(x - xr).max())
        scale = float(jnp.abs(x).max())
        assert err <= scale / 127.0 + 1e-6

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), blocks=st.integers(1, 5))
    def test_quant_roundtrip_hypothesis(self, seed, blocks):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(
            rng.normal(size=(optim.QBLOCK * blocks,)).astype(np.float32) * 10
        )
        q, s = optim.quantize_blockwise(x)
        xr = optim.dequantize_blockwise(q, s)
        per_block_scale = np.abs(np.asarray(x)).reshape(blocks, -1).max(1)
        err_b = np.abs(np.asarray(x - xr)).reshape(blocks, -1).max(1)
        assert (err_b <= per_block_scale / 127.0 + 1e-6).all()

    def test_converges_on_quadratic(self):
        params, grads_of, target = _quad_problem(n=optim.QBLOCK)
        st_ = optim.adam8bit_init({"w": (optim.QBLOCK,)})
        for step in range(300):
            params, st_ = optim.adam8bit_update(
                params, grads_of(params), st_, jnp.int32(step), 0.05
            )
        # int8 moments: looser tolerance than f32 Adam
        assert float(jnp.abs(params["w"] - target).max()) < 5e-2

    def test_state_sizes(self):
        st_ = optim.adam8bit_init({"w": (100,)})  # padded to one block
        assert st_["w.mq"].shape == (optim.QBLOCK,)
        assert st_["w.mq"].dtype == jnp.int8
        assert st_["w.ms"].shape == (1,)


class TestGaLore:
    def test_newton_schulz_invsqrt(self):
        rng = np.random.default_rng(2)
        M = rng.normal(size=(6, 6)).astype(np.float32)
        S = jnp.asarray(M @ M.T + 0.5 * np.eye(6, dtype=np.float32))
        Z = optim.newton_schulz_invsqrt(S)
        I_hat = Z @ S @ Z
        np.testing.assert_allclose(np.asarray(I_hat), np.eye(6), atol=5e-2)

    def test_orthonormalize(self):
        rng = np.random.default_rng(3)
        Y = jnp.asarray(rng.normal(size=(20, 5)).astype(np.float32))
        Q = optim.orthonormalize(Y)
        np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(5), atol=5e-2)

    def test_subspace_iteration_matches_svd(self):
        # low-rank-dominated G: subspace iteration must find the top space
        rng = np.random.default_rng(4)
        U = np.linalg.qr(rng.normal(size=(30, 4)))[0]
        V = np.linalg.qr(rng.normal(size=(20, 4)))[0]
        G = (U * np.asarray([10, 8, 6, 4])) @ V.T + 0.01 * rng.normal(size=(30, 20))
        G = jnp.asarray(G.astype(np.float32))
        P0 = optim.orthonormalize(
            jnp.asarray(rng.normal(size=(30, 4)).astype(np.float32))
        )
        P = optim.subspace_iter(G, P0, iters=4)
        # principal angle check: ||U U^T P|| ~ 1 per column
        overlap = np.linalg.norm(U.T @ np.asarray(P), axis=0)
        assert (overlap > 0.98).all()

    def test_targets_select_adapted_linears_only(self):
        shapes = {
            "layers.0.attn.q.w": (32, 32),
            "embed.w": (256, 32),
            "lnf.g": (32,),
            "head.w": (32, 256),
        }
        t = optim.galore_targets(shapes, rank=8)
        assert set(t) == {"layers.0.attn.q.w"}

    def test_projected_state_is_small(self):
        shapes = {"layers.0.attn.q.w": (64, 48)}
        st_ = optim.galore_init(shapes, rank=8, seed=0)
        assert st_["layers.0.attn.q.w.P"].shape == (48, 8)  # right side (d>p)
        assert st_["layers.0.attn.q.w.m"].shape == (64, 8)

    def test_galore_update_reduces_loss(self):
        rng = np.random.default_rng(5)
        target = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
        params = {"layers.0.mlp.up.w": jnp.zeros((32, 24), jnp.float32)}
        st_ = optim.galore_init({"layers.0.mlp.up.w": (32, 24)}, rank=8)
        for step in range(200):
            g = {"layers.0.mlp.up.w": params["layers.0.mlp.up.w"] - target}
            params, st_ = optim.galore_update(
                params, g, st_, jnp.int32(step), 0.05, rank=8, refresh_every=50
            )
        err = float(jnp.abs(params["layers.0.mlp.up.w"] - target).mean())
        assert err < 0.5  # projected optimizer still makes clear progress


class TestSchedule:
    def test_warmup_then_decay(self):
        lr0 = float(optim.lr_schedule(jnp.int32(0), 1.0, 10, 100))
        lr_w = float(optim.lr_schedule(jnp.int32(5), 1.0, 10, 100))
        lr_peak = float(optim.lr_schedule(jnp.int32(10), 1.0, 10, 100))
        lr_end = float(optim.lr_schedule(jnp.int32(100), 1.0, 10, 100))
        assert lr0 == 0.0
        assert 0 < lr_w < lr_peak
        assert abs(lr_peak - 1.0) < 1e-5
        assert abs(lr_end - 0.1) < 1e-5

    def test_monotone_decay_after_warmup(self):
        vals = [
            float(optim.lr_schedule(jnp.int32(s), 1.0, 10, 200))
            for s in range(10, 200, 10)
        ]
        assert all(a >= b for a, b in zip(vals, vals[1:]))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

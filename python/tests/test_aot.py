"""AOT contract tests: the manifest + HLO emission that the rust runtime
programs against. A broken input ordering, missing support sidecar, or
dtype mislabel here is exactly the class of bug the integration suite
would only catch after a slow compile — so we pin the contract at the
python layer too."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs


@pytest.fixture(scope="module")
def bundle_dir():
    with tempfile.TemporaryDirectory() as d:
        cfg = configs.get("tiny")
        man = aot.emit_bundle(cfg, "sltrain", os.path.join(d, "tiny_sltrain"), batch=4)
        yield os.path.join(d, "tiny_sltrain"), man


class TestManifest:
    def test_files_exist(self, bundle_dir):
        d, man = bundle_dir
        for e in man["entrypoints"].values():
            assert os.path.exists(os.path.join(d, e["file"])), e["file"]
        assert os.path.exists(os.path.join(d, "manifest.json"))
        # manifest on disk parses and equals the returned one
        with open(os.path.join(d, "manifest.json")) as f:
            assert json.load(f) == man

    def test_train_step_io_ordering(self, bundle_dir):
        _, man = bundle_dir
        e = man["entrypoints"]["train_step"]
        pnames = [p["name"] for p in man["params"]]
        cnames = [c["name"] for c in man["consts"]]
        onames = [o["name"] for o in man["opt_state"]]
        assert e["inputs"] == ["__step", "__tokens"] + cnames + pnames + onames
        assert e["outputs"] == ["__loss"] + pnames + onames

    def test_support_sidecars_match(self, bundle_dir):
        d, man = bundle_dir
        assert man["supports"], "sltrain must have supports"
        for name, sup in man["supports"].items():
            raw = open(os.path.join(d, sup["file"]), "rb").read()
            assert len(raw) == sup["nnz"] * 4
            idx = np.frombuffer(raw, dtype=np.uint32)
            assert (np.diff(idx.astype(np.int64)) > 0).all(), f"{name} not sorted"
            # matches the const spec length
            cshape = next(c["shape"] for c in man["consts"] if c["name"] == name)
            assert cshape == [sup["nnz"]]

    def test_param_count_consistency(self, bundle_dir):
        _, man = bundle_dir
        total = sum(int(np.prod(p["shape"])) for p in man["params"])
        assert total == man["n_params"]

    def test_trainable_flags(self, bundle_dir):
        _, man = bundle_dir
        # sltrain: everything trainable (no w0)
        assert all(p["trainable"] for p in man["params"])

    def test_hlo_text_is_parseable_hlo(self, bundle_dir):
        d, man = bundle_dir
        text = open(os.path.join(d, man["entrypoints"]["train_step"]["file"])).read()
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text


class TestFreezeVariants:
    def test_freeze_lowrank_trains_only_vals(self):
        cfg = configs.get("tiny")
        b = aot.build_bundle(cfg, "sltrain", batch=4, freeze_lowrank=True)
        trainable = b["model"].trainable
        assert trainable and all(n.endswith(".vals") for n in trainable)
        # optimizer state exists only for vals
        assert all(".vals." in n or n.endswith((".vals.m", ".vals.v")) for n in b["onames"])

    def test_ft_freeze_base(self):
        cfg = configs.get("tiny")
        b = aot.build_bundle(cfg, "sltrain_ft", batch=4, ft_freeze_base=True)
        t = set(b["model"].trainable)
        assert "embed.w" not in t
        assert not any(n.endswith(".g") for n in t)
        assert "head.w" in t
        assert not any(n.endswith(".w0") for n in t)

    def test_sltrain_ft_has_w0(self):
        cfg = configs.get("tiny")
        b = aot.build_bundle(cfg, "sltrain_ft", batch=4)
        assert any(n.endswith(".w0") for n in b["pnames"])
        assert any(n.endswith(".vals") for n in b["pnames"])


class TestOverrides:
    def test_galore_gets_galore_optimizer(self):
        cfg = configs.get("tiny")
        b = aot.build_bundle(cfg, "galore", batch=4)
        assert b["opt_kind"] == "galore"
        assert any(n.endswith(".P") for n in b["onames"])

    def test_opt8bit_state_is_int8(self):
        cfg = configs.get("tiny")
        b = aot.build_bundle(cfg, "sltrain", batch=4, opt8bit=True)
        assert b["opt_kind"] == "adam8bit"
        mq = [n for n in b["onames"] if n.endswith(".mq")]
        assert mq
        assert all(b["odtypes"][n] == jnp.int8 for n in mq)


class TestHloRoundtrip:
    def test_lowered_train_step_runs_in_jax(self):
        """The ultimate python-side check: execute the bundle's train_step
        end-to-end and confirm the loss is finite and decreasing-ish."""
        cfg = configs.get("tiny")
        b = aot.build_bundle(cfg, "sltrain", batch=4)
        m = b["model"]
        out = b["init_fn"](0)
        params = list(out[: len(b["pnames"])])
        opt = list(out[len(b["pnames"]) :])
        consts = [jnp.asarray(m.supports[n]) for n in b["cnames"]]
        rng = np.random.default_rng(0)
        step = jax.jit(b["train_step"])
        losses = []
        for i in range(6):
            toks = jnp.asarray(
                rng.integers(0, cfg.vocab, size=(4, cfg.seq_len)).astype(np.int32)
            )
            o = step(jnp.int32(i), toks, *consts, *params, *opt)
            losses.append(float(o[0]))
            params = list(o[1 : 1 + len(b["pnames"])])
            opt = list(o[1 + len(b["pnames"]) :])
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

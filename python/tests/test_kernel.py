"""Kernel-vs-oracle tests: the CORE correctness signal for L1.

Every Pallas kernel in `compile.kernels.sl_linear` is pinned to the
pure-jnp oracle in `compile.kernels.ref`, across shape/tile/sparsity
sweeps (hypothesis) and directed edge cases (empty-ish supports, single
rows, non-divisible tiles, support on tile boundaries).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sl_linear as sl

jax.config.update("jax_enable_x64", False)


def mk(seed, d, r, p, m, delta, zero_b=False):
    rng = np.random.default_rng(seed)
    B = jnp.asarray(
        np.zeros((d, r), np.float32)
        if zero_b
        else rng.normal(size=(d, r)).astype(np.float32)
    )
    A = jnp.asarray(rng.normal(size=(r, p)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    idx = ref.random_support(seed + 1, d, p, delta)
    vals = jnp.asarray(rng.normal(size=(len(idx),)).astype(np.float32))
    return x, B, A, idx, vals


def assert_close(a, b, atol=2e-4, rtol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------- densify


class TestDensify:
    def test_basic(self):
        x, B, A, idx, vals = mk(0, 32, 4, 48, 8, 0.05)
        assert_close(
            sl.sl_densify(B, A, idx, vals, 0.5, bd=16, bp=16),
            ref.densify(B, A, jnp.asarray(idx), vals, 0.5),
        )

    def test_uneven_tiles(self):
        x, B, A, idx, vals = mk(1, 33, 5, 47, 8, 0.07)
        assert_close(
            sl.sl_densify(B, A, idx, vals, 1.0, bd=16, bp=16),
            ref.densify(B, A, jnp.asarray(idx), vals, 1.0),
        )

    def test_single_nnz(self):
        rng = np.random.default_rng(3)
        B = jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32))
        A = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
        idx = np.asarray([255], np.int32)  # last entry, tile corner
        vals = jnp.asarray([7.0], jnp.float32)
        W = sl.sl_densify(B, A, idx, vals, 1.0, bd=8, bp=8)
        assert_close(W, ref.densify(B, A, jnp.asarray(idx), vals, 1.0))

    def test_saturated_support(self):
        # delta=1.0: every entry in the support (scatter-add everywhere).
        x, B, A, idx, vals = mk(4, 12, 3, 20, 4, 1.0)
        assert len(idx) == 12 * 20
        assert_close(
            sl.sl_densify(B, A, idx, vals, 2.0, bd=8, bp=8),
            ref.densify(B, A, jnp.asarray(idx), vals, 2.0),
        )

    def test_zero_b_is_pure_sparse(self):
        # SLTrain init: B = 0 so W == S at step 0.
        x, B, A, idx, vals = mk(5, 24, 4, 24, 4, 0.1, zero_b=True)
        W = sl.sl_densify(B, A, idx, vals, 1.0, bd=16, bp=16)
        dense = np.zeros(24 * 24, np.float32)
        dense[np.asarray(idx)] += np.asarray(vals)
        assert_close(W, dense.reshape(24, 24))

    @settings(max_examples=25, deadline=None)
    @given(
        d=st.integers(4, 70),
        r=st.integers(1, 12),
        p=st.integers(4, 70),
        delta=st.floats(0.005, 0.3),
        bd=st.sampled_from([8, 16, 32]),
        bp=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 10_000),
    )
    def test_hypothesis_sweep(self, d, r, p, delta, bd, bp, seed):
        x, B, A, idx, vals = mk(seed, d, r, p, 2, delta)
        assert_close(
            sl.sl_densify(B, A, idx, vals, 0.3, bd=bd, bp=bp),
            ref.densify(B, A, jnp.asarray(idx), vals, 0.3),
        )


# ---------------------------------------------------------------- fused matmul


class TestFusedMatmul:
    def test_basic(self):
        x, B, A, idx, vals = mk(10, 32, 4, 48, 8, 0.05)
        assert_close(
            sl.sl_matmul(x, B, A, idx, vals, 0.5, bm=4, bd=16, bp=16),
            ref.sl_linear(x, B, A, jnp.asarray(idx), vals, 0.5),
        )

    def test_single_row(self):
        x, B, A, idx, vals = mk(11, 40, 6, 24, 1, 0.05)
        assert_close(
            sl.sl_matmul(x, B, A, idx, vals, 1.0, bm=8, bd=8, bp=8),
            ref.sl_linear(x, B, A, jnp.asarray(idx), vals, 1.0),
        )

    def test_reduction_across_many_d_tiles(self):
        x, B, A, idx, vals = mk(12, 128, 8, 16, 4, 0.02)
        assert_close(
            sl.sl_matmul(x, B, A, idx, vals, 1.0, bm=4, bd=16, bp=16),
            ref.sl_linear(x, B, A, jnp.asarray(idx), vals, 1.0),
            atol=5e-4,
            rtol=5e-4,
        )

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 20),
        d=st.integers(4, 60),
        r=st.integers(1, 10),
        p=st.integers(4, 60),
        delta=st.floats(0.01, 0.25),
        seed=st.integers(0, 10_000),
    )
    def test_hypothesis_sweep(self, m, d, r, p, delta, seed):
        x, B, A, idx, vals = mk(seed, d, r, p, m, delta)
        assert_close(
            sl.sl_matmul(x, B, A, idx, vals, 0.7, bm=8, bd=16, bp=16),
            ref.sl_linear(x, B, A, jnp.asarray(idx), vals, 0.7),
            atol=5e-4,
            rtol=5e-4,
        )


# ---------------------------------------------------------------- gradients


class TestGradients:
    def _check(self, seed, d, r, p, m, delta, use_pallas):
        x, B, A, idx, vals = mk(seed, d, r, p, m, delta)
        scale = 0.4
        f = sl.make_sl_linear(idx, p, scale, use_pallas=use_pallas)

        def loss(x, B, A, vals):
            return jnp.sum(jnp.tanh(f(x, B, A, vals)))

        def loss_ref(x, B, A, vals):
            return jnp.sum(
                jnp.tanh(ref.sl_linear(x, B, A, jnp.asarray(idx), vals, scale))
            )

        g = jax.grad(loss, argnums=(0, 1, 2, 3))(x, B, A, vals)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, B, A, vals)
        for a, b in zip(g, gr):
            assert_close(a, b, atol=1e-3, rtol=1e-3)

    def test_vjp_pallas(self):
        self._check(20, 32, 4, 40, 6, 0.05, True)

    def test_vjp_jnp_path(self):
        self._check(21, 32, 4, 40, 6, 0.05, False)

    def test_vjp_uneven(self):
        self._check(22, 35, 5, 41, 7, 0.08, True)

    def test_closed_form_matches_autodiff(self):
        # eq. (2) formulas (ref.sl_linear_grads) vs jax.grad of the oracle.
        x, B, A, idx, vals = mk(23, 28, 4, 36, 5, 0.06)
        scale = 0.9
        dy = jnp.ones((5, 36), jnp.float32)
        dx, dB, dA, dv = ref.sl_linear_grads(
            x, B, A, jnp.asarray(idx), vals, dy, scale
        )

        def loss(x, B, A, vals):
            return jnp.sum(ref.sl_linear(x, B, A, jnp.asarray(idx), vals, scale))

        gx, gB, gA, gv = jax.grad(loss, argnums=(0, 1, 2, 3))(x, B, A, vals)
        assert_close(dx, gx)
        assert_close(dB, gB)
        assert_close(dA, gA)
        assert_close(dv, gv)

    @settings(max_examples=10, deadline=None)
    @given(
        d=st.integers(8, 40),
        r=st.integers(1, 8),
        p=st.integers(8, 40),
        m=st.integers(1, 8),
        seed=st.integers(0, 1000),
    )
    def test_hypothesis_vjp(self, d, r, p, m, seed):
        self._check(seed, d, r, p, m, 0.05, True)


# ---------------------------------------------------------------- dvals kernel


class TestDvals:
    def test_chunked_equals_dense_gather(self):
        x, B, A, idx, vals = mk(30, 24, 4, 32, 6, 0.15)
        dy = jnp.asarray(
            np.random.default_rng(31).normal(size=(6, 32)).astype(np.float32)
        )
        dv = sl.sl_dvals(x, dy, idx, 32, chunk=7)  # deliberately odd chunk
        dW = x.T @ dy
        expected = dW.reshape(-1)[jnp.asarray(idx)]
        assert_close(dv, expected, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------- support utils


class TestSupport:
    def test_random_support_properties(self):
        idx = ref.random_support(0, 50, 60, 0.1)
        assert len(idx) == round(0.1 * 50 * 60)
        assert len(np.unique(idx)) == len(idx)  # no duplicates
        assert idx.min() >= 0 and idx.max() < 50 * 60
        assert (np.diff(idx) > 0).all()  # sorted

    def test_support_deterministic_by_seed(self):
        a = ref.random_support(7, 30, 30, 0.05)
        b = ref.random_support(7, 30, 30, 0.05)
        c = ref.random_support(8, 30, 30, 0.05)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_transpose_support_roundtrip(self):
        d, p = 13, 17
        idx = ref.random_support(3, d, p, 0.2)
        t = sl._transpose_support(idx, d, p)
        tt = sl._transpose_support(t, p, d)
        assert np.array_equal(np.sort(tt), np.sort(idx))
        # value pairing is preserved position-wise
        assert np.array_equal(tt, idx)

    def test_bucket_support_covers_all_entries(self):
        d, p, bd, bp = 40, 56, 16, 16
        gd, gp = -(-d // bd), -(-p // bp)
        idx = ref.random_support(5, d, p, 0.1)
        tl, tg, cap = sl.bucket_support(idx, p, bd, bp, gd, gp)
        assert (tl >= -1).all()
        n_placed = int((tl >= 0).sum())
        assert n_placed == len(idx)
        # every gather slot with a valid local index refers to a distinct val
        gathered = tg[tl >= 0]
        assert len(np.unique(gathered)) == len(idx)

    def test_bucket_reconstructs_dense(self):
        d, p, bd, bp = 24, 24, 8, 8
        gd, gp = d // bd, p // bp
        idx = ref.random_support(6, d, p, 0.15)
        vals = np.random.default_rng(7).normal(size=len(idx)).astype(np.float32)
        tl, tg, cap = sl.bucket_support(idx, p, bd, bp, gd, gp)
        dense = np.zeros((d, p), np.float32)
        for t in range(gd * gp):
            ti, tj = t // gp, t % gp
            for k in range(cap):
                if tl[t, k] >= 0:
                    rl, cl = tl[t, k] // bp, tl[t, k] % bp
                    dense[ti * bd + rl, tj * bp + cl] += vals[tg[t, k]]
        expected = np.zeros(d * p, np.float32)
        np.add.at(expected, np.asarray(idx), vals)
        np.testing.assert_allclose(dense, expected.reshape(d, p), atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
